"""Cost-based BGP planning: per-star strategy choice and join ordering.

The single-star BENCH matrix already shows neither fixed strategy
dominating -- factorized wins ground-arm lookups (one vectorized
comparison over AMI molecule rows vs a full predicate-slice scan), raw
wins off-SP variable arms (the factorized fall-back pays a dedup sort
over molecule-expanded pairs).  The planner makes that trade per star
from three cheap inputs, all O(log) index probes against structures the
engine already maintains:

* **AM / AMI ratios** -- ``FactorizedGraph.am/ami`` plus the raw-typed
  residue off ``GraphIndex.entities_of_class``: how much of the class
  the molecule table speaks for, and how many rows a molecule-level
  evaluation touches;
* **arm selectivity** -- ``GraphIndex.pred_object_count / pred_count``
  (per-predicate sorted-object cache): how many candidates a ground arm
  keeps;
* **filter selectivity** -- range position of the constant in the
  predicate's sorted object column.

Join order is greedy smallest-frontier-first over *connected* stars
(shared variables), so the molecule-level join probes the deferred side
with the most selective concrete side available.  ``strategy="raw"`` /
``"factorized"`` remain as caller overrides; ``"auto"`` is the planner.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.fgraph import FactorizedGraph

from .algebra import BGPQuery, Filter, StarPattern
from .exec import deferral_eligible

@dataclasses.dataclass
class CostModel:
    """Per-operation constants of the planner's cost formulas.

    The defaults are a prior-centered least-squares fit against
    observed warm latencies of the BENCH bgp workloads
    (``repro.query.bgp.calibrate.fit_cost_model``, ``l2=0.5``, prior =
    the original hand-tuned anchors "factorized wins in-SP ground
    lookups" / "raw wins off-SP variable arms"), normalized so
    ``c_mol == 1``.  ``c_mix`` prices the granularity crossing when a
    deferred (molecule-level) relation joins an entity-level one --
    each surviving molecule row pays a membership expansion at join
    time, which the pre-fit model did not charge for at all (the ~25%
    planner miss on filtered 3-star chains, ROADMAP item 1').
    """
    c_mol: float = 1.0       # per molecule row compared (vectorized ==)
    c_residual: float = 0.52  # per raw-typed entity on the residual
                              #   path (prior-pinned: the bench graph
                              #   factorizes fully, so no data here)
    c_emit: float = 0.57     # per emitted entity binding row
    c_scan: float = 0.28     # per triple scanned in a predicate slice
    c_pair: float = 1.37     # per pair through the factorized off-SP
                             #   expansion (dedup sort of _arm_pairs)
    c_mix: float = 5.6       # per deferred molecule row crossing into
                             #   an entity-granularity join

    FEATURES = ("mol", "residual", "emit", "scan", "pair", "mix")

    def as_array(self) -> np.ndarray:
        return np.array([self.c_mol, self.c_residual, self.c_emit,
                         self.c_scan, self.c_pair, self.c_mix])

    @classmethod
    def from_array(cls, a) -> "CostModel":
        return cls(*(float(x) for x in a))


#: module-level model consulted by :func:`plan_star` /
#: :func:`plan_bgp` when the caller does not pass one explicitly;
#: mutate or replace (``planner.COST = fitted``) to recalibrate a
#: whole process.
COST = CostModel()


@dataclasses.dataclass(frozen=True)
class StarPlan:
    index: int
    strategy: str               # "raw" | "factorized"
    deferred: bool              # molecule-granularity evaluation
    est_rows: float             # entity-level cardinality estimate
    est_frontier: float         # relation rows this star contributes
    cost: float                 # chosen strategy's cost estimate


@dataclasses.dataclass(frozen=True)
class BGPPlan:
    order: tuple[int, ...]
    stars: tuple[StarPlan, ...]     # indexed by star position in the query

    @property
    def strategies(self) -> tuple[str, ...]:
        return tuple(s.strategy for s in self.stars)


def _class_stats(fg: FactorizedGraph, cid: int, cache: dict | None
                 ) -> tuple[int, int, int, int]:
    """(semantic N, AMI, AM, raw residue) of a class, cached per epoch."""
    key = ("cstats", int(cid))
    if cache is not None and key in cache:
        return cache[key]
    n_typed = int(fg.store.index.entities_of_class(int(cid)).shape[0])
    ami = fg.ami(cid)
    am = fg.am(cid) if ami else 0
    raw_pop = max(n_typed - ami, 0)
    out = (am + raw_pop, ami, am, raw_pop)
    if cache is not None:
        cache[key] = out
    return out


def _filter_selectivity(fg: FactorizedGraph, p: int, f: Filter) -> float:
    objs = fg.store.index.pred_objects_sorted(int(p))
    n = int(objs.shape[0])
    if n == 0:
        return 1.0
    lo = int(np.searchsorted(objs, f.value, side="left"))
    hi = int(np.searchsorted(objs, f.value, side="right"))
    k = {"==": hi - lo, "!=": n - (hi - lo), "<": lo, "<=": hi,
         ">": n - hi, ">=": n - lo}[f.op]
    return max(k, 1) / n


def _star_estimates(fg: FactorizedGraph, star: StarPattern,
                    filters: list[Filter], cache: dict | None
                    ) -> dict:
    idx = fg.store.index
    ground_sel = 1.0
    scan_cost = 0.0
    for p, o in star.ground_arms:
        n = idx.pred_count(p)
        scan_cost += n
        ground_sel *= (idx.pred_object_count(p, o) / n) if n else 0.0
    fsel = 1.0
    var_prop = {v: p for p, v in star.var_arms}
    for f in filters:
        p = var_prop.get(f.var)
        if p is not None:
            fsel *= _filter_selectivity(fg, p, f)
    if star.class_id is not None:
        n_sem, ami, am, raw_pop = _class_stats(fg, star.class_id, cache)
    else:
        n_sem = min((idx.pred_object_count(p, o)
                     for p, o in star.ground_arms),
                    default=max((idx.pred_count(p)
                                 for p, _ in star.var_arms), default=0))
        ami = am = 0
        raw_pop = n_sem
    table = fg.tables.get(int(star.class_id)) \
        if star.class_id is not None else None
    off_sp_pairs = 0.0
    for p, _ in star.var_arms:
        if table is None or table.col_of(p) is None:
            off_sp_pairs += idx.pred_count(p)
    return {
        "n_sem": n_sem, "ami": ami, "am": am, "raw_pop": raw_pop,
        "ground_sel": ground_sel, "fsel": fsel, "scan": scan_cost,
        "off_sp_pairs": off_sp_pairs,
        "est_rows": max(n_sem * ground_sel * fsel, 1.0),
        "mol_rows": max(ami * ground_sel * fsel, 0.0),
    }


def plan_star(fg: FactorizedGraph, query: BGPQuery, si: int,
              strategy: str = "auto", cache: dict | None = None,
              cost_model: CostModel | None = None,
              mixed_partners: int = 0) -> StarPlan:
    """Cost one star.  ``mixed_partners`` is the number of already-
    planned non-deferred stars this star shares a variable with; each
    charges ``c_mix`` per surviving molecule row on the deferred
    option (the granularity-crossing expansion the join must pay)."""
    cm = cost_model if cost_model is not None else COST
    star = query.stars[si]
    filters = [f for f in query.filters if f.var in star.variables]
    est = _star_estimates(fg, star, filters, cache)
    eligible = deferral_eligible(fg, star, filters, cache=cache)

    cost_deferred = (cm.c_mol * est["ami"]
                     + cm.c_residual * est["raw_pop"]
                     + cm.c_emit * est["mol_rows"]
                     + cm.c_mix * mixed_partners * est["mol_rows"]
                     ) if eligible else np.inf
    cost_fact = (cm.c_mol * est["ami"] + cm.c_residual * est["raw_pop"]
                 + cm.c_emit * est["est_rows"]
                 + cm.c_pair * est["off_sp_pairs"])
    cost_raw = cm.c_scan * (est["n_sem"] + est["scan"]
                            + sum(fg.store.index.pred_count(p)
                                  for p, _ in star.var_arms)) \
        + cm.c_emit * est["est_rows"]

    if strategy == "raw":
        choice, deferred, cost = "raw", False, cost_raw
    elif strategy == "factorized":
        deferred = eligible
        choice = "factorized"
        cost = cost_deferred if eligible else cost_fact
    else:
        options = [(cost_deferred, "factorized", True),
                   (cost_fact, "factorized", False),
                   (cost_raw, "raw", False)]
        cost, choice, deferred = min(options, key=lambda t: t[0])
    frontier = (est["mol_rows"] + est["raw_pop"] * est["ground_sel"]
                if deferred else est["est_rows"])
    return StarPlan(index=si, strategy=choice, deferred=deferred,
                    est_rows=est["est_rows"],
                    est_frontier=max(frontier, 1.0), cost=float(cost))


def _join_order(query: BGPQuery, plans: list[StarPlan]) -> tuple[int, ...]:
    """Greedy smallest-frontier-first, preferring stars connected (by a
    shared variable) to the set already joined; disconnected components
    enter by frontier size (cross product deferred to the end)."""
    remaining = set(range(len(plans)))
    var_sets = [set(s.variables) for s in query.stars]
    order: list[int] = []
    bound: set[str] = set()
    while remaining:
        connected = [i for i in remaining if var_sets[i] & bound]
        pool = connected if connected else list(remaining)
        nxt = min(pool, key=lambda i: (plans[i].est_frontier, i))
        order.append(nxt)
        bound |= var_sets[nxt]
        remaining.discard(nxt)
    return tuple(order)


def plan_bgp(fg: FactorizedGraph, query: BGPQuery, *,
             strategy: str = "auto", cache: dict | None = None,
             cost_model: CostModel | None = None) -> BGPPlan:
    """Plan a BGP.  ``strategy`` is the caller override: ``"auto"`` runs
    the cost model per star, ``"raw"``/``"factorized"`` pin every star
    (deferral still applies under ``"factorized"`` when sound).

    Under ``"auto"`` a second pass re-prices deferred stars that share
    a variable with a non-deferred partner: the first pass costs each
    star in isolation, but a molecule-granularity relation joined
    against an entity-granularity one pays a membership expansion per
    molecule row (``CostModel.c_mix``).  Re-pricing may flip such stars
    to entity granularity; each flip can expose new mixed edges, so the
    pass iterates to a fixpoint (deferrals only ever decrease, so at
    most ``len(stars)`` rounds)."""
    if strategy not in ("auto", "raw", "factorized"):
        raise ValueError(f"unknown BGP strategy {strategy!r}")
    cm = cost_model if cost_model is not None else COST
    plans = [plan_star(fg, query, i, strategy=strategy, cache=cache,
                       cost_model=cm)
             for i in range(len(query.stars))]
    if strategy == "auto" and len(plans) > 1:
        var_sets = [set(s.variables) for s in query.stars]
        for _ in range(len(plans)):
            flipped = False
            for i, sp in enumerate(plans):
                if not sp.deferred:
                    continue
                mixed = sum(1 for j, other in enumerate(plans)
                            if j != i and not other.deferred
                            and var_sets[i] & var_sets[j])
                if not mixed:
                    continue
                repl = plan_star(fg, query, i, strategy="auto",
                                 cache=cache, cost_model=cm,
                                 mixed_partners=mixed)
                flipped |= repl.deferred != sp.deferred
                plans[i] = repl
            if not flipped:
                break
    return BGPPlan(order=_join_order(query, plans), stars=tuple(plans))

"""Full BGP engine on the compact form.

Layers (ROADMAP item 1):

* :mod:`algebra`   -- ``StarPattern`` / ``Filter`` / ``BGPQuery`` /
  ``BGPBindings``: multi-star basic graph patterns with range/equality
  filters over dictionary ids.
* :mod:`exec`      -- molecule-granularity execution: deferred subject
  columns, AMI x AMI cross-star joins, vectorized filter pushdown into
  molecule object columns, member materialization last.
* :mod:`planner`   -- the cost model replacing the caller ``strategy=``
  flag: per-star raw-vs-factorized choice (``CostModel`` constants,
  mixed-slot join re-pricing) and greedy connected join ordering from
  AM/AMI ratios and arm/filter selectivities.
* :mod:`calibrate` -- least-squares fit of the ``CostModel`` constants
  from timed workloads (the committed defaults come from the BENCH
  harness running this).
* :mod:`reference` -- the independent semantics oracle used by the
  property tests.

Entry point for callers: ``repro.query.QueryEngine.query_bgp``.
"""
from .algebra import BGPBindings, BGPQuery, Filter, StarPattern, is_var
from .calibrate import calibration_report, fit_cost_model
from .exec import deferral_eligible, execute_bgp
from .planner import BGPPlan, CostModel, StarPlan, plan_bgp
from .reference import eval_bgp_reference

__all__ = [
    "BGPBindings", "BGPQuery", "Filter", "StarPattern", "is_var",
    "deferral_eligible", "execute_bgp",
    "BGPPlan", "CostModel", "StarPlan", "plan_bgp",
    "calibration_report", "fit_cost_model",
    "eval_bgp_reference",
]

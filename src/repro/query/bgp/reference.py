"""Reference BGP evaluator: the semantics oracle for property tests.

Deliberately shares **no** join or deferral machinery with ``exec``:
each star evaluates with ``eval_raw`` over a *plain* store (the
``expand()`` of the graph under test), stars combine with a plain
python hash join, and filters apply last on fully materialized rows.
Slow and obviously-correct; ``tests/test_bgp.py`` asserts every
engine strategy (planner-chosen, fixed-raw, fixed-factorized, with and
without filters) produces the same canonical binding set.
"""
from __future__ import annotations

import numpy as np

from repro.core.triples import TripleStore

from ..star import eval_raw
from .algebra import BGPBindings, BGPQuery, StarPattern, is_var


def _star_rows(store: TripleStore, star: StarPattern
               ) -> tuple[tuple[str, ...], list[tuple[int, ...]]]:
    from ..star import StarQuery
    q = StarQuery(
        arms=tuple((p, None if is_var(o) else int(o)) for p, o in star.arms),
        class_id=star.class_id)
    b = eval_raw(store, q)
    cols = (star.subject,) + tuple(v for _, v in star.var_arms)
    rows = []
    for row in b.rows().tolist():
        # repeated variables inside a star must bind equal values
        env: dict[str, int] = {}
        ok = True
        for v, val in zip(cols, row):
            if v in env and env[v] != val:
                ok = False
                break
            env[v] = int(val)
        if ok:
            rows.append(env)
    keep = []
    seen = set()
    for v in cols:
        if v not in seen:
            seen.add(v)
            keep.append(v)
    return tuple(keep), [tuple(e[v] for v in keep) for e in rows]


def eval_bgp_reference(store: TripleStore, query: BGPQuery) -> BGPBindings:
    """Evaluate a BGP on a plain store by per-star raw evaluation and
    nested hash joins, filters applied post-hoc."""
    cols: tuple[str, ...] = ()
    rows: list[tuple[int, ...]] = []
    for si, star in enumerate(query.stars):
        scols, srows = _star_rows(store, star)
        if si == 0:
            cols, rows = scols, srows
            continue
        shared = [v for v in scols if v in cols]
        new = [v for v in scols if v not in cols]
        idx_a = [cols.index(v) for v in shared]
        idx_s = [scols.index(v) for v in shared]
        idx_new = [scols.index(v) for v in new]
        table: dict[tuple, list[tuple]] = {}
        for r in srows:
            table.setdefault(tuple(r[j] for j in idx_s), []).append(
                tuple(r[j] for j in idx_new))
        joined = []
        for r in rows:
            for ext in table.get(tuple(r[j] for j in idx_a), ()):
                joined.append(r + ext)
        cols = cols + tuple(new)
        rows = joined
    out = []
    for r in rows:
        env = dict(zip(cols, r))
        if all(f.apply(np.asarray([env[f.var]]))[0]
               for f in query.filters):
            out.append(r)
    arr = (np.asarray(out, np.int64) if out
           else np.empty((0, len(cols)), np.int64))
    perm = [cols.index(v) for v in query.variables]
    return BGPBindings(query.variables, arr[:, perm])

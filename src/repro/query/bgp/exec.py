"""BGP execution on the compact form: molecule-level joins, deferred
materialization, and filter pushdown.

The paper's query claim is that G' answers star lookups at **AMI** cost
(one molecule row speaks for all of its members).  This module extends
that claim across star boundaries: a multi-star BGP is executed as a
sequence of relation joins where factorized stars stay at *molecule
granularity* until the very end --

* a factorized star evaluates to a relation whose subject column holds
  **surrogate ids** for the absorbed population (one row per matching
  molecule, var-arm columns read straight off the molecule object
  matrix) plus concrete rows for the class's raw residue;
* FILTER constraints on in-SP variables are **pushed down** to one
  vectorized comparison over the molecule object column -- a molecule
  that fails excludes every member at once, before any member is
  emitted;
* joins between such relations run molecule-to-molecule: the concrete
  side's entity values are mapped to their surrogate
  (``FactorizedGraph.molecule_of``, one binary-search join) and matched
  against the deferred side's surrogate rows, so the intermediate
  cardinality is AMI x AMI instead of AM x AM (recorded in the stats
  and gated in ``benchmarks/check_snapshot.py``);
* member materialization (the instanceOf-CSR gather) happens once, on
  the final joined relation.

Deferral is *guarded*: it is only sound when every (s, p, v) pair of an
absorbed member for the star's properties derives from the class's own
molecules.  Online updates can attach extra raw pairs to members (or
absorb the same entity into another class whose SP shares a property);
``deferral_eligible`` detects both with per-predicate membership probes
and falls back to the concrete strategy -- correctness never depends on
the graph being freshly compacted.
"""
from __future__ import annotations

import dataclasses
import itertools

import numpy as np

from repro.core.fgraph import FactorizedGraph
from repro.core.index import csr_take, in_sorted

from ..star import (StarQuery, _arm_pairs, _arm_subject_set, _intersect,
                    _join_vars, eval_factorized, eval_raw, match_molecules)
from .algebra import BGPBindings, BGPQuery, Filter, StarPattern, is_var


@dataclasses.dataclass
class Relation:
    """Intermediate BGP relation.

    ``mixed`` maps a column index to a class id: that column may hold
    surrogate ids of the class (each such row stands for the molecule's
    whole member set) interleaved with concrete entity ids -- the id
    spaces are disjoint, so a membership probe against the class's
    surrogate vector separates them exactly.
    """

    columns: tuple[str, ...]
    rows: np.ndarray                      # (R, C) int64
    mixed: dict[int, int] = dataclasses.field(default_factory=dict)

    @property
    def n_rows(self) -> int:
        return int(self.rows.shape[0])


def _empty_stats() -> dict:
    return {"max_intermediate": 0, "star_rows": [], "deferred_stars": 0,
            "joins": 0, "filters_pushed": 0, "result_rows": 0}


# ---------------------------------------------------------------------------
# deferral guard
# ---------------------------------------------------------------------------

def _class_member_set(fg: FactorizedGraph, table, cache: dict | None
                      ) -> np.ndarray:
    key = ("members", table.class_id)
    if cache is not None and key in cache:
        return cache[key]
    mem, _ = fg.members_of(table.surrogates)
    mem = np.unique(mem.astype(np.int64))
    if cache is not None:
        cache[key] = mem
    return mem


def _prop_pure(fg: FactorizedGraph, table, p: int,
               cache: dict | None) -> bool:
    """True iff every (s, p, v) pair of an absorbed member of the class
    derives from the class's own molecule column: no raw pair on a
    member, no pair through another class's molecule."""
    key = ("pure", table.class_id, int(p))
    if cache is not None and key in cache:
        return cache[key]
    sl = fg.store.index.pred_slice(int(p))
    subs = sl[:, 0].astype(np.int64)
    own = in_sorted(subs, table.surrogates.astype(np.int64))
    others = subs[~own]
    ok = True
    if others.shape[0]:
        osg = fg.is_surrogate(others)
        check = others[~osg]
        if osg.any():
            mem2, _ = fg.members_of(others[osg])
            check = np.concatenate([check, mem2.astype(np.int64)])
        if check.shape[0]:
            mem = _class_member_set(fg, table, cache)
            ok = not in_sorted(check, mem).any()
    if cache is not None:
        cache[key] = ok
    return ok


def deferral_eligible(fg: FactorizedGraph, star: StarPattern,
                      filters: list[Filter] | tuple[Filter, ...] = (),
                      cache: dict | None = None) -> bool:
    """Can this star evaluate at molecule granularity?"""
    if star.class_id is None:
        return False
    table = fg.tables.get(int(star.class_id))
    if table is None or table.n_molecules == 0:
        return False
    if any(table.col_of(p) is None for p, _ in star.arms):
        return False            # off-SP arm: molecule columns can't answer
    if any(f.var == star.subject for f in filters):
        return False            # subject constrained by value: stay concrete
    if star.subject in [v for _, v in star.var_arms]:
        return False            # ?s p ?s needs entity-level equality
    return all(_prop_pure(fg, table, p, cache) for p, _ in star.arms)


# ---------------------------------------------------------------------------
# per-star evaluation
# ---------------------------------------------------------------------------

def _collapse_dup_vars(cols: tuple[str, ...], rows: np.ndarray
                       ) -> tuple[tuple[str, ...], np.ndarray]:
    """Repeated variables inside one star require equality; keep the
    first occurrence of each column."""
    seen: dict[str, int] = {}
    keep: list[int] = []
    mask = np.ones(rows.shape[0], bool)
    for i, v in enumerate(cols):
        if v in seen:
            mask &= rows[:, i] == rows[:, seen[v]]
        else:
            seen[v] = i
            keep.append(i)
    if len(keep) == len(cols):
        return cols, rows
    return tuple(cols[i] for i in keep), rows[mask][:, keep]


def _apply_filters_concrete(cols: tuple[str, ...], rows: np.ndarray,
                            filters) -> np.ndarray:
    for f in filters:
        if f.var in cols:
            rows = rows[f.apply(rows[:, cols.index(f.var)])]
    return rows


def _star_query(star: StarPattern) -> StarQuery:
    return StarQuery(
        arms=tuple((p, None if is_var(o) else int(o)) for p, o in star.arms),
        class_id=star.class_id)


def _eval_star_concrete(fg: FactorizedGraph, raw_store, star: StarPattern,
                        filters, strategy: str) -> Relation:
    q = _star_query(star)
    if strategy == "raw":
        b = eval_raw(raw_store, q)
    else:
        b = eval_factorized(fg, q)
    cols = (star.subject,) + tuple(v for _, v in star.var_arms)
    cols, rows = _collapse_dup_vars(cols, b.rows())
    rows = _apply_filters_concrete(cols, rows, filters)
    return Relation(cols, rows)


def _residual_rows(fg: FactorizedGraph, star: StarPattern, filters,
                   cols: tuple[str, ...]) -> np.ndarray:
    """Concrete rows for the class's raw population (incomplete
    molecules, post-delete decompactions, online residue) -- the
    Def. 4.11 fall-back of the deferred path."""
    cid = int(star.class_id)
    cand = fg.store.index.entities_of_class(cid)
    cand = cand[~fg.is_surrogate(cand)].astype(np.int64)
    for p, o in star.ground_arms:
        if cand.shape[0] == 0:
            break
        cand = _intersect(cand, _arm_subject_set(fg, p, o))
    full_cols = (star.subject,) + tuple(v for _, v in star.var_arms)
    if cand.shape[0] == 0:
        return np.empty((0, len(cols)), np.int64)
    b = _join_vars(cand, [p for p, _ in star.var_arms],
                   lambda p, c: _arm_pairs(fg, p, c))
    ccols, rows = _collapse_dup_vars(full_cols, b.rows())
    assert ccols == cols
    return _apply_filters_concrete(cols, rows, filters)


def _eval_star_deferred(fg: FactorizedGraph, star: StarPattern, filters,
                        stats: dict, mol_rows: np.ndarray | None = None
                        ) -> Relation:
    """Molecule-granularity evaluation: one row per matching molecule
    (subject column = surrogate id), filters pushed into the object
    columns, plus the class's concrete residue."""
    cid = int(star.class_id)
    table = fg.tables[cid]
    rows_idx = (match_molecules(table, star.ground_arms)
                if mol_rows is None else np.asarray(mol_rows))
    # -- filter pushdown: one comparison per molecule answers every
    #    member of that molecule at once
    if filters and rows_idx.shape[0]:
        mask = np.ones(rows_idx.shape[0], bool)
        for p, vname in star.var_arms:
            for f in filters:
                if f.var == vname:
                    mask &= f.apply(
                        table.objects[rows_idx, table.col_of(p)]
                        .astype(np.int64))
                    stats["filters_pushed"] += 1
        rows_idx = rows_idx[mask]
    n_var = len(star.var_arms)
    def_rows = np.empty((rows_idx.shape[0], 1 + n_var), np.int64)
    def_rows[:, 0] = table.surrogates[rows_idx]
    for k, (p, _) in enumerate(star.var_arms):
        def_rows[:, 1 + k] = table.objects[rows_idx, table.col_of(p)]
    cols = (star.subject,) + tuple(v for _, v in star.var_arms)
    cols2, def_rows = _collapse_dup_vars(cols, def_rows)
    res_rows = _residual_rows(fg, star, filters, cols2)
    stats["deferred_stars"] += 1
    return Relation(cols2, np.concatenate([def_rows, res_rows], axis=0),
                    mixed={0: cid})


def eval_star(fg: FactorizedGraph, star: StarPattern, filters,
              strategy: str, deferred: bool, stats: dict, *,
              raw_store=None, mol_rows: np.ndarray | None = None
              ) -> Relation:
    if deferred and strategy == "factorized":
        return _eval_star_deferred(fg, star, filters, stats,
                                   mol_rows=mol_rows)
    return _eval_star_concrete(fg, raw_store, star, filters, strategy)


# ---------------------------------------------------------------------------
# joins
# ---------------------------------------------------------------------------

def _void(keys: np.ndarray) -> np.ndarray:
    """Structured 1-D view of (R, K) int64 key rows -- lexicographically
    sortable/searchable as one scalar (the ``core.index`` idiom)."""
    arr = np.ascontiguousarray(keys, np.int64)
    dt = np.dtype([(f"f{i}", np.int64) for i in range(arr.shape[1])])
    return arr.view(dt).ravel()


def _match_pairs(akeys: np.ndarray, bkeys: np.ndarray
                 ) -> tuple[np.ndarray, np.ndarray]:
    """All (ai, bi) index pairs with equal key rows (sort-merge with
    multiplicities: standard BGP join semantics).  Sorts whichever side
    is SMALLER and binary-searches the other: a molecule-deferred
    relation joining a raw one sorts AMI rows, not the entity-level
    side -- and the mixed-slot combo loop re-sorts only the small side."""
    ra, rb = akeys.shape[0], bkeys.shape[0]
    if ra == 0 or rb == 0:
        return np.empty((0,), np.int64), np.empty((0,), np.int64)
    if ra < rb:
        bi, ai = _match_pairs(bkeys, akeys)
        return ai, bi
    bv = _void(bkeys)
    order = np.argsort(bv, kind="stable")
    bs = bv[order]
    av = _void(akeys)
    lo = np.searchsorted(bs, av, side="left")
    hi = np.searchsorted(bs, av, side="right")
    counts = hi - lo
    ai = np.repeat(np.arange(ra), counts)
    bi = order[csr_take(lo, counts)]
    return ai, bi


def join(fg: FactorizedGraph, a: Relation, b: Relation,
         stats: dict) -> Relation:
    """Join two relations on their shared variables.

    A shared column that is molecule-deferred on one side joins at
    molecule level: the concrete side's entity values map to their
    surrogate in the deferred side's class (``molecule_of``), so the
    deferred side's AMI rows are probed directly -- its members are
    never enumerated.  A column deferred on *both* sides materializes
    the right side first (targeted, that column only).
    """
    shared = [v for v in a.columns if v in b.columns]
    for v in shared:
        ca, cb = a.columns.index(v), b.columns.index(v)
        if ca in a.mixed and cb in b.mixed:
            b = _materialize_col(fg, b, cb)
    stats["joins"] += 1
    if not shared:
        ai = np.repeat(np.arange(a.n_rows), b.n_rows)
        bi = np.tile(np.arange(b.n_rows), a.n_rows)
        acols: list[int] = []
        bcols: list[int] = []
    else:
        acols = [a.columns.index(v) for v in shared]
        bcols = [b.columns.index(v) for v in shared]
        # slots where one side is molecule-deferred: the concrete side
        # probes it per-molecule via entity -> surrogate mapping
        mslots = []
        for j in range(len(shared)):
            if acols[j] in a.mixed:
                mslots.append((j, "a", a.mixed[acols[j]]))
            elif bcols[j] in b.mixed:
                mslots.append((j, "b", b.mixed[bcols[j]]))
        base_ak = np.ascontiguousarray(a.rows[:, acols], np.int64)
        base_bk = np.ascontiguousarray(b.rows[:, bcols], np.int64)
        ai_parts, bi_parts = [], []
        # each combination routes every row pair through exactly one
        # variant per slot (surrogate ids and entity ids are disjoint),
        # so the union is duplicate-free
        for combo in itertools.product((0, 1), repeat=len(mslots)):
            ak, bk = base_ak, base_bk
            for (j, side, cid), bit in zip(mslots, combo):
                if not bit:
                    continue
                if side == "a":     # a deferred: lift b's entities
                    if bk is base_bk:
                        bk = bk.copy()
                    bk[:, j] = fg.molecule_of(cid, base_bk[:, j])
                else:               # b deferred: lift a's entities
                    if ak is base_ak:
                        ak = ak.copy()
                    ak[:, j] = fg.molecule_of(cid, base_ak[:, j])
            ai, bi = _match_pairs(ak, bk)
            ai_parts.append(ai)
            bi_parts.append(bi)
        ai = np.concatenate(ai_parts)
        bi = np.concatenate(bi_parts)
    b_only = [j for j, v in enumerate(b.columns) if v not in a.columns]
    cols = a.columns + tuple(b.columns[j] for j in b_only)
    rows = np.concatenate(
        [a.rows[ai], b.rows[bi][:, b_only] if b_only
         else np.empty((ai.shape[0], 0), np.int64)], axis=1)
    # a shared column that was deferred resolves to the concrete side's
    # entity value: the joined row stands for that one member
    for j, v in enumerate(shared):
        if acols[j] in a.mixed:
            rows[:, acols[j]] = b.rows[bi, bcols[j]]
    mixed = {c: cid for c, cid in a.mixed.items()
             if a.columns[c] not in shared}
    for k, j in enumerate(b_only):
        if j in b.mixed and b.columns[j] not in shared:
            mixed[len(a.columns) + k] = b.mixed[j]
    return Relation(cols, rows, mixed)


# ---------------------------------------------------------------------------
# materialization
# ---------------------------------------------------------------------------

def _materialize_col(fg: FactorizedGraph, rel: Relation, col: int
                     ) -> Relation:
    """Expand one molecule-deferred column: each surrogate-valued row
    becomes one row per member (single instanceOf-CSR gather)."""
    cid = rel.mixed[col]
    mixed = {c: k for c, k in rel.mixed.items() if c != col}
    table = fg.tables.get(cid)
    rows = rel.rows
    if table is None or rows.shape[0] == 0 or table.n_molecules == 0:
        return Relation(rel.columns, rows, mixed)
    is_sg = in_sorted(rows[:, col], table.surrogates.astype(np.int64))
    if not is_sg.any():
        return Relation(rel.columns, rows, mixed)
    sg_rows = rows[is_sg]
    ents, src = fg.members_of(sg_rows[:, col])
    expanded = sg_rows[src]
    expanded[:, col] = ents
    return Relation(rel.columns,
                    np.concatenate([rows[~is_sg], expanded], axis=0), mixed)


def materialize(fg: FactorizedGraph, rel: Relation) -> Relation:
    for col in sorted(rel.mixed):
        rel = _materialize_col(fg, rel, col)
    return rel


# ---------------------------------------------------------------------------
# top-level execution
# ---------------------------------------------------------------------------

def execute_bgp(fg: FactorizedGraph, query: BGPQuery, plan, *,
                raw_store=None, mol_rows: dict[int, np.ndarray] | None = None,
                posthoc_filters: bool = False
                ) -> tuple[BGPBindings, dict]:
    """Run a planned BGP.  ``plan`` is a ``planner.BGPPlan``; fixed
    strategies come from planning with ``strategy="raw"/"factorized"``.

    ``mol_rows`` optionally injects pre-computed molecule-match rows per
    star index (the batched device path); ``posthoc_filters=True``
    evaluates the pattern unfiltered and applies every FILTER on the
    fully materialized result -- the baseline the BENCH ``filter``
    workload compares pushdown against.
    """
    stats = _empty_stats()
    filters = () if posthoc_filters else query.filters
    applied: set[Filter] = set()
    rel: Relation | None = None
    for si in plan.order:
        sp = plan.stars[si]
        star = query.stars[si]
        sfilters = [f for f in filters if f.var in star.variables]
        r = eval_star(fg, star, sfilters, sp.strategy, sp.deferred, stats,
                      raw_store=raw_store,
                      mol_rows=None if mol_rows is None
                      else mol_rows.get(si))
        applied.update(sfilters)
        stats["star_rows"].append(r.n_rows)
        stats["max_intermediate"] = max(stats["max_intermediate"], r.n_rows)
        rel = r if rel is None else join(fg, rel, r, stats)
        stats["max_intermediate"] = max(stats["max_intermediate"],
                                        rel.n_rows)
    rel = materialize(fg, rel)
    rows, cols = rel.rows, rel.columns
    for f in filters:
        if f not in applied:
            rows = rows[f.apply(rows[:, cols.index(f.var)])]
    if posthoc_filters:
        rows = _apply_filters_concrete(cols, rows, query.filters)
    perm = [cols.index(v) for v in query.variables]
    out = BGPBindings(query.variables, rows[:, perm])
    stats["result_rows"] = out.n_rows
    return out, stats

"""Batched device star-query evaluation: one lowering per query stack.

The molecule-match join of ``eval_factorized`` -- "which of the class's
M molecules satisfy this query's ground arms?" -- is exactly the shape
the candidate-batched sweep engine already compiles: a (M, K) parent
buffer, a per-candidate column mask, and a row-signature group-by.  This
module reuses that machinery wholesale:

* the molecule table pads to the same power-of-two ``(m_b, k_b)``
  bucket (``core.sweep.bucket_rows`` / ``bucket_cols``) and uploads to
  device ONCE per (engine, class);
* a stack of Q queries becomes a ``(q_b, k_b)`` 0/1 column-mask stack
  plus an aligned value stack, chunked at ``MAX_SWEEP_CANDIDATES`` and
  padded with all-zero no-op rows (``bucket_candidates`` rung);
* one jitted call computes, per query, the masked molecule signatures
  (``kernels.ops.row_signature`` -- the Pallas ``sig_hash`` kernel with
  the query axis as the grid axis, padded rows carrying the shared
  sentinel) and compares them against the query tuple's own signature:
  ``(Q, M)`` hit booleans come back from a single lowering.

Signatures are 64-bit hashes, so hits are *verified exactly on host*
(an O(hits * K) comparison) before members are emitted -- a collision
can cost a verification, never a wrong answer.  Trace accounting rides
``core.sweep.TRACE_COUNTS`` under the ``"query"`` kind, so the bench
snapshot gates zero warm retraces on this path exactly like the sweep.
"""
from __future__ import annotations

import functools

import numpy as np

from repro.core.fgraph import FactorizedGraph, MoleculeTable
from repro.core.sweep import (MAX_SWEEP_CANDIDATES, _note_trace,
                              bucket_candidates, bucket_cols, bucket_rows,
                              register_stats_reset)

from .star import Bindings, StarQuery, eval_factorized

# executed-lowering accounting for the batched query path (mirrors
# core.sweep.EXEC_STATS: one "batch" = one query_batch call)
QUERY_EXEC = {"lowerings": 0, "batches": 0}


def reset_query_stats() -> None:
    QUERY_EXEC["lowerings"] = 0
    QUERY_EXEC["batches"] = 0


# core.sweep.reset_trace_stats() is the one reset the bench harness
# calls between cells; hooking in here keeps QUERY_EXEC from bleeding
# one cell's lowerings into the next cell's snapshot numbers
register_stats_reset(reset_query_stats)


@functools.lru_cache(maxsize=None)
def _jax():
    import jax
    import jax.numpy as jnp
    return jax, jnp


@functools.lru_cache(maxsize=None)
def _match_fn(use_kernel: bool):
    """Build (once) the jitted molecule-match over a padded bucket.

    Keyed only by the bucket shape: molecule values, query masks and
    query values are all traced, so every (class, query stack) pair that
    lands in the same ``(m_b, k_b, q_b)`` bucket is a jit cache hit.
    """
    jax, jnp = _jax()
    from repro.kernels import ops as kops

    def match(mols, valid, masks, vals):
        _note_trace("query", mols.shape + (masks.shape[0],))
        stack = mols[None, :, :] * masks[:, None, :]        # (Q, M, K)
        sig = kops.row_signature(stack, valid=valid,
                                 use_kernel=use_kernel)     # (Q, M, 2)
        qsig = kops.row_signature((vals * masks)[:, None, :],
                                  use_kernel=use_kernel)    # (Q, 1, 2)
        return jnp.all(sig == qsig, axis=-1) & valid[None, :]

    return jax.jit(match)


class _TableBuffer:
    """One bucket-padded on-device copy of a class's molecule table."""

    def __init__(self, table: MoleculeTable) -> None:
        jax, jnp = _jax()
        m, k = table.objects.shape
        self.m, self.k = m, k
        self.m_bucket = bucket_rows(m)
        self.k_bucket = bucket_cols(k)
        buf = np.zeros((self.m_bucket, self.k_bucket), np.int32)
        buf[:m, :k] = table.objects
        self.dev = jnp.asarray(buf)
        self.valid = jnp.asarray(np.arange(self.m_bucket) < m)


def match_molecules_batch(buf: _TableBuffer, table: MoleculeTable,
                          arm_stacks: list[list[tuple[int, int]]],
                          use_kernel: bool = True) -> list[np.ndarray]:
    """Molecule-table rows matching each query's ground SP arms, for a
    whole stack of queries in one lowering per candidate chunk."""
    _, jnp = _jax()
    n_q = len(arm_stacks)
    out: list[np.ndarray] = []
    for lo in range(0, n_q, MAX_SWEEP_CANDIDATES):
        chunk = arm_stacks[lo:lo + MAX_SWEEP_CANDIDATES]
        q_b = bucket_candidates(len(chunk))
        masks = np.zeros((q_b, buf.k_bucket), np.int32)
        vals = np.zeros((q_b, buf.k_bucket), np.int32)
        for qi, arms in enumerate(chunk):
            for p, o in arms:
                j = table.col_of(p)
                masks[qi, j] = 1
                vals[qi, j] = o
        QUERY_EXEC["lowerings"] += 1
        hits = np.asarray(_match_fn(use_kernel)(
            buf.dev, buf.valid, jnp.asarray(masks), jnp.asarray(vals)))
        for qi, arms in enumerate(chunk):
            rows = np.flatnonzero(hits[qi, :buf.m])
            if rows.size and arms:
                # exact host verification: a signature collision may
                # only ever cost this check, never a wrong binding
                ok = np.ones(rows.shape[0], bool)
                for p, o in arms:
                    ok &= table.objects[rows, table.col_of(p)] == o
                rows = rows[ok]
            out.append(rows)
    return out


class QueryEngine:
    """Star-query engine over one :class:`FactorizedGraph`.

    ``strategy="factorized"`` evaluates on G' directly;
    ``strategy="raw"`` evaluates on the expanded plain graph (built
    lazily, cached) -- the baseline a stock engine would run, and the
    latency comparison the bench snapshot records.  ``query_batch``
    with ``backend="device"`` routes every class-constrained query
    whose ground arms live inside the class's SP through the batched
    molecule-match lowering; everything else falls back to the host
    path query-by-query.
    """

    def __init__(self, fgraph: FactorizedGraph,
                 raw_store=None, *, use_kernel: bool = True,
                 epoch: int = 0, metrics=None) -> None:
        self.fgraph = fgraph
        self._raw = raw_store
        self.use_kernel = bool(use_kernel)
        self.epoch = int(epoch)
        self.metrics = metrics
        # device buffers are keyed (epoch, class): an engine rebound to
        # a new snapshot epoch can never serve a stale molecule table.
        # The cache is BOUNDED to the latest two epochs -- a reader may
        # still hold the previous snapshot mid-wave, but anything older
        # is unreachable and evicts on rebind (otherwise a long-running
        # online recompaction leaks device buffers one epoch at a time)
        self._bufs: dict[tuple[int, int], _TableBuffer] = {}
        self.buffer_evictions = 0
        # planner/deferral probe cache (class stats, per-prop deferral
        # guards) -- valid for one fgraph only, dropped on rebind
        self._bgp_cache: dict = {}

    def rebind(self, fgraph: FactorizedGraph, epoch: int) -> None:
        """Swap in a new snapshot's fgraph.  Device buffers older than
        the previous epoch are evicted (counted in the
        ``query.buffer_evictions`` channel when a metrics hub is
        attached); the raw-store cache drops with them.  The jit cache
        is untouched -- same bucket shapes re-lower zero times after a
        swap."""
        if epoch == self.epoch and fgraph is self.fgraph:
            return
        keep = {int(epoch), self.epoch}
        self.fgraph = fgraph
        self.epoch = int(epoch)
        self._raw = None
        n_before = len(self._bufs)
        self._bufs = {k: v for k, v in self._bufs.items()
                      if k[0] in keep}
        evicted = n_before - len(self._bufs)
        if evicted:
            self.buffer_evictions += evicted
            if self.metrics is not None:
                self.metrics.observe("query.buffer_evictions", evicted)
        self._bgp_cache = {}

    @property
    def raw_store(self):
        if self._raw is None:
            self._raw = self.fgraph.expand()
        return self._raw

    def query(self, q: StarQuery, strategy: str = "factorized") -> Bindings:
        from .star import eval_raw
        if strategy == "factorized":
            return eval_factorized(self.fgraph, q)
        if strategy == "raw":
            return eval_raw(self.raw_store, q)
        raise ValueError(f"unknown query strategy: {strategy!r}")

    def _buffer(self, class_id: int) -> _TableBuffer:
        key = (self.epoch, class_id)
        buf = self._bufs.get(key)
        if buf is None:
            buf = _TableBuffer(self.fgraph.tables[class_id])
            self._bufs[key] = buf
        return buf

    def query_bgp(self, q, strategy: str = "auto", backend: str = "host",
                  posthoc_filters: bool = False,
                  return_stats: bool = False):
        """Answer a multi-star :class:`~repro.query.bgp.BGPQuery`.

        ``strategy="auto"`` runs the cost-based planner per star;
        ``"raw"`` / ``"factorized"`` pin every star (the old caller
        flag, kept as an override).  ``backend="device"`` routes every
        deferred star's molecule match through the batched sweep-bucket
        lowering -- grouped per class, zero warm retraces.
        ``posthoc_filters=True`` is the bench baseline: filters applied
        on fully materialized bindings instead of molecule columns.
        """
        from .bgp.exec import execute_bgp
        from .bgp.planner import plan_bgp
        plan = plan_bgp(self.fgraph, q, strategy=strategy,
                        cache=self._bgp_cache)
        mol_rows = None
        if backend == "device":
            QUERY_EXEC["batches"] += 1
            mol_rows = {}
            by_class: dict[int, list[int]] = {}
            for sp in plan.stars:
                if sp.deferred:
                    by_class.setdefault(
                        int(q.stars[sp.index].class_id), []).append(sp.index)
            for cid, idxs in by_class.items():
                table = self.fgraph.tables[cid]
                stacks = [q.stars[i].ground_arms for i in idxs]
                rows = match_molecules_batch(
                    self._buffer(cid), table, stacks,
                    use_kernel=self.use_kernel)
                for i, r in zip(idxs, rows):
                    mol_rows[i] = r
        needs_raw = any(sp.strategy == "raw" for sp in plan.stars)
        out, stats = execute_bgp(
            self.fgraph, q, plan,
            raw_store=self.raw_store if needs_raw else None,
            mol_rows=mol_rows, posthoc_filters=posthoc_filters)
        stats["plan"] = plan
        return (out, stats) if return_stats else out

    def query_batch(self, queries, strategy: str = "factorized",
                    backend: str = "host") -> list[Bindings]:
        queries = list(queries)
        if strategy != "factorized" or backend != "device":
            return [self.query(q, strategy) for q in queries]
        QUERY_EXEC["batches"] += 1
        out: list[Bindings | None] = [None] * len(queries)
        # group device-eligible queries per class: the whole group's
        # molecule match runs in one lowering per chunk
        groups: dict[int, list[int]] = {}
        for i, q in enumerate(queries):
            table = self.fgraph.tables.get(int(q.class_id)) \
                if q.class_id is not None else None
            if table is not None and table.n_molecules and all(
                    table.col_of(p) is not None
                    for p, o in q.ground_arms):
                groups.setdefault(int(q.class_id), []).append(i)
            else:
                out[i] = eval_factorized(self.fgraph, q)
        for cid, idxs in groups.items():
            table = self.fgraph.tables[cid]
            stacks = [queries[i].ground_arms for i in idxs]
            rows = match_molecules_batch(self._buffer(cid), table, stacks,
                                         use_kernel=self.use_kernel)
            for i, r in zip(idxs, rows):
                out[i] = eval_factorized(self.fgraph, queries[i],
                                         _mol_rows=r)
        return out  # type: ignore[return-value]

"""Star-query engine over the compact form (no expansion).

The paper's motivation is that frequent star patterns hurt graph size
AND query processing; this package makes the second half measurable.
``StarQuery`` describes a star BGP (subject variable, (property,
object-or-variable) arms, optional class), and :class:`QueryEngine`
answers it with two provably-equivalent strategies:

    from repro.api import Compactor
    from repro.query import QueryEngine, StarQuery

    comp = Compactor(); comp.run(store)
    eng = QueryEngine(comp.fgraph)
    q = StarQuery(arms=((p_procedure, sensor7), (p_time, None)),
                  class_id=observation)
    eng.query(q)                       # factorized: molecule-table match
    eng.query(q, strategy="raw")       # baseline: index joins on expand()
    eng.query_batch(qs, backend="device")   # one lowering per stack

``raw`` scales per-arm with AM (every entity repeats every edge);
``factorized`` scales with AMI (one molecule row answers all of its
entities through the ``instanceOf`` CSR).  The batched device path
reuses the sweep engine's bucket ladder and ``sig_hash`` kernels for
the molecule-match join.  Equivalence of all three is property-tested
(``tests/test_query.py``) and gated on the bench snapshot.

Multi-star BGPs with FILTERs ride the :mod:`repro.query.bgp` subsystem
(``QueryEngine.query_bgp``): molecule-level cross-star joins, filter
pushdown into molecule object columns, and a cost-based planner that
replaces the ``strategy=`` flag (kept as an override).
"""
from .batch import (QUERY_EXEC, QueryEngine, match_molecules_batch,  # noqa: F401
                    reset_query_stats)
from .bgp import (BGPBindings, BGPPlan, BGPQuery, Filter,  # noqa: F401
                  StarPattern, eval_bgp_reference, execute_bgp, plan_bgp)
from .star import (Bindings, StarQuery, eval_factorized, eval_raw,  # noqa: F401
                   match_molecules)

__all__ = ["StarQuery", "Bindings", "QueryEngine", "eval_raw",
           "eval_factorized", "match_molecules", "match_molecules_batch",
           "QUERY_EXEC", "reset_query_stats",
           "BGPQuery", "BGPBindings", "BGPPlan", "Filter", "StarPattern",
           "plan_bgp", "execute_bgp", "eval_bgp_reference"]

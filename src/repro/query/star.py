"""Star BGP queries and their two evaluation strategies.

A *star query* is the BGP shape the paper's compaction targets: one
subject variable constrained by a set of (property, object) arms plus an
optional class:

    ?s  type C .  ?s p1 o1 .  ?s p2 ?v2 .  ...

``StarQuery`` carries the arms as ``(property_id, object_id-or-None)``
pairs (``None`` = variable object); the answer is a :class:`Bindings`
set -- one row per (subject, variable objects...) combination.

Two provably-equivalent strategies evaluate it:

``eval_raw``        -- over a *plain* graph (the original G, or the
    ``expand()`` of a factorized one): per-arm ``searchsorted`` joins on
    the ``GraphIndex`` vertical partitions, sorted-set intersections for
    ground arms, vectorized subject joins for variable arms.  This is
    what a stock engine does, and its per-arm cost scales with the
    class's **AM** (every entity carries every edge).

``eval_factorized`` -- over a :class:`~repro.core.fgraph.FactorizedGraph`
    directly, **no expansion**: ground arms inside a class's SP match
    against the (M, K) molecule table (one vectorized comparison over
    AMI rows), and each matching molecule emits all of its entities in
    one ``instanceOf``-CSR gather -- a surrogate hit answers many
    entities at once.  Arms outside the SP (and entities that stayed
    raw: incomplete molecules, post-delete decompactions, unfactorized
    classes) fall back to the residual raw triples, where every arm is
    still answered with one Def. 4.11 rewriting step: raw subjects ``\\cup``
    members of matching surrogates.  Per-arm cost scales with **AMI**,
    which is the paper's "queries get faster on G'" claim made
    executable (gated in ``benchmarks/check_snapshot.py``).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Sequence

import numpy as np

from repro.core.fgraph import FactorizedGraph
from repro.core.index import csr_take, in_sorted
from repro.core.triples import TripleStore


@dataclasses.dataclass(frozen=True)
class StarQuery:
    """One star BGP: subject variable + arms (+ optional class)."""

    arms: tuple[tuple[int, int | None], ...]
    class_id: int | None = None

    def __post_init__(self):
        object.__setattr__(
            self, "arms",
            tuple((int(p), None if o is None else int(o))
                  for p, o in self.arms))

    @property
    def ground_arms(self) -> list[tuple[int, int]]:
        return [(p, o) for p, o in self.arms if o is not None]

    @property
    def var_props(self) -> list[int]:
        return [p for p, o in self.arms if o is None]


@dataclasses.dataclass
class Bindings:
    """Answer set: subjects plus one object column per variable arm."""

    subjects: np.ndarray            # (R,)
    var_props: tuple[int, ...]      # variable arms, in query-arm order
    var_objects: np.ndarray         # (R, V)

    @property
    def n_rows(self) -> int:
        return int(self.subjects.shape[0])

    def rows(self) -> np.ndarray:
        """(R, 1 + V) int64 binding rows (subject first)."""
        subs = np.asarray(self.subjects, np.int64).reshape(-1, 1)
        vo = np.asarray(self.var_objects, np.int64)
        if vo.ndim != 2:
            vo = vo.reshape(subs.shape[0], -1 if vo.size else 0)
        return np.concatenate([subs, vo], axis=1)

    def canonical(self) -> np.ndarray:
        """Sorted-unique binding rows -- strategy-order-independent."""
        r = self.rows()
        if r.shape[0] == 0:
            return r
        return np.unique(r, axis=0)

    def same_as(self, other: "Bindings") -> bool:
        a, b = self.canonical(), other.canonical()
        return a.shape == b.shape and bool((a == b).all())


def _intersect(cand: np.ndarray | None, subs: np.ndarray) -> np.ndarray:
    if cand is None:
        return subs
    return np.intersect1d(cand, subs, assume_unique=True)


def _join_vars(subjects: np.ndarray, var_props: Sequence[int],
               pairs_of: Callable[[int, np.ndarray],
                                  tuple[np.ndarray, np.ndarray]]
               ) -> Bindings:
    """Expand candidate subjects over the variable arms.

    ``pairs_of(p, cand)`` returns the (s, v) pairs of property ``p``
    sorted by subject (``cand`` -- the sorted-unique current candidate
    set -- lets strategies skip materializing pairs that cannot join);
    each join keeps subjects that have >= 1 value and multiplies binding
    rows per value (standard BGP semantics).
    """
    cols: list[np.ndarray] = []
    subjects = np.asarray(subjects)
    unique_subjects = True     # ground/class candidates come in deduped
    for p in var_props:
        s_col, v_col = pairs_of(
            p, subjects if unique_subjects else np.unique(subjects))
        unique_subjects = False     # joins may multiply rows
        lo = np.searchsorted(s_col, subjects, side="left")
        hi = np.searchsorted(s_col, subjects, side="right")
        counts = hi - lo
        v = v_col[csr_take(lo, counts)]
        subjects = np.repeat(subjects, counts)
        cols = [np.repeat(c, counts) for c in cols]
        cols.append(v)
    vo = (np.stack(cols, axis=1) if cols
          else np.empty((subjects.shape[0], 0), np.int64))
    return Bindings(subjects=subjects,
                    var_props=tuple(int(p) for p in var_props),
                    var_objects=vo)


# ---------------------------------------------------------------------------
# raw strategy (plain graphs)
# ---------------------------------------------------------------------------

def eval_raw(store: TripleStore, q: StarQuery) -> Bindings:
    """Evaluate on a plain (non-factorized) graph via index joins.

    Ground arms are sorted-set intersections over the per-predicate
    vertical partitions; variable arms are vectorized subject joins.
    Running this on a factorized store would miss absorbed entities --
    use :func:`eval_factorized` (or expand first).
    """
    idx = store.index
    cand: np.ndarray | None = None
    if q.class_id is not None:
        cand = idx.entities_of_class(int(q.class_id))
    for p, o in q.ground_arms:
        sl = idx.pred_slice(p)
        subs = sl[sl[:, 2] == o, 0]     # (s, o)-sorted slice: s unique
        cand = _intersect(cand, subs)

    def pairs_of(p: int, cand: np.ndarray
                 ) -> tuple[np.ndarray, np.ndarray]:
        sl = idx.pred_slice(p)
        return sl[:, 0], sl[:, 2]

    var_props = q.var_props
    if cand is None:
        if not var_props:
            raise ValueError("star query needs a class or at least one arm")
        cand = np.unique(idx.pred_slice(var_props[0])[:, 0])
    return _join_vars(cand, var_props, pairs_of)


# ---------------------------------------------------------------------------
# factorized strategy (no expansion)
# ---------------------------------------------------------------------------

def _expand_subjects(fg: FactorizedGraph, subs: np.ndarray) -> np.ndarray:
    """Def. 4.11 rewriting of a subject set: surrogates are replaced by
    their members (one CSR gather), raw subjects pass through."""
    is_sg = fg.is_surrogate(subs)
    mem, _ = fg.members_of(subs[is_sg])
    return np.union1d(subs[~is_sg], mem)


def _arm_subject_set(fg: FactorizedGraph, p: int, o: int) -> np.ndarray:
    """Sorted-unique *entities* satisfying ``(?s p o)`` on G'."""
    sl = fg.store.index.pred_slice(p)
    return _expand_subjects(fg, sl[sl[:, 2] == o, 0])


def _arm_pairs(fg: FactorizedGraph, p: int,
               cand: np.ndarray | None = None
               ) -> tuple[np.ndarray, np.ndarray]:
    """Semantic (s, v) pairs of property ``p``, sorted by s.

    Raw pairs pass through; surrogate pairs expand to one pair per
    member.  When ``cand`` (a sorted-unique subject set) is given, pairs
    are filtered to it *before* the dedup sort -- a var-arm join over a
    selective candidate set never pays an O(AM log AM) sort.  Pairs
    derivable both raw and through a molecule (or through two molecules
    of overlapping classes) dedup.
    """
    sl = fg.store.index.pred_slice(p)
    is_sg = fg.is_surrogate(sl[:, 0])
    raw = sl[~is_sg]
    sg_rows = sl[is_sg]
    if not is_sg.any():
        # pure raw partition: the (s, o)-sorted slice is already a
        # sorted-unique pair list -- no sort needed
        if cand is None:
            return raw[:, 0].astype(np.int64), raw[:, 2].astype(np.int64)
        keep = in_sorted(raw[:, 0].astype(np.int64),
                         np.sort(np.asarray(cand, np.int64)))
        return raw[keep, 0].astype(np.int64), raw[keep, 2].astype(np.int64)
    if cand is None:
        # full expansion: every surrogate arm row emits one pair per
        # member through the CSR
        mem, src = fg.members_of(sg_rows[:, 0])
        s = np.concatenate([raw[:, 0], mem]).astype(np.int64)
        v = np.concatenate([raw[:, 2], sg_rows[src, 2]]).astype(np.int64)
    else:
        # candidate-driven: walk cand -> its surrogates (instanceOf
        # partition is subject-sorted) -> the surrogates' (p, v) rows,
        # so cost scales with the candidate set, not with AM
        cand = np.sort(np.asarray(cand, np.int64))
        keep = in_sorted(raw[:, 0].astype(np.int64), cand)
        raw = raw[keep]
        inst = fg.store.index.pred_slice(fg.store.INSTANCE_OF)
        lo = np.searchsorted(inst[:, 0], cand, side="left")
        hi = np.searchsorted(inst[:, 0], cand, side="right")
        counts = hi - lo
        cs = np.repeat(cand, counts)
        csg = inst[csr_take(lo, counts), 2]
        # values of (csg, p): extents into the surrogate rows of slice
        sg_s = sg_rows[:, 0]
        lo2 = np.searchsorted(sg_s, csg, side="left")
        hi2 = np.searchsorted(sg_s, csg, side="right")
        c2 = hi2 - lo2
        vv = sg_rows[csr_take(lo2, c2), 2]
        if raw.shape[0] == 0 and (counts <= 1).all():
            # every candidate derives through at most one surrogate and
            # nothing is raw: pairs are already sorted-unique by
            # construction (cand ascending, one extent each)
            return np.repeat(cs, c2).astype(np.int64), vv.astype(np.int64)
        s = np.concatenate([raw[:, 0], np.repeat(cs, c2)]).astype(np.int64)
        v = np.concatenate([raw[:, 2], vv]).astype(np.int64)
    pairs = np.unique(np.stack([s, v], axis=1), axis=0)
    return pairs[:, 0], pairs[:, 1]


def _class_members(fg: FactorizedGraph, class_id: int) -> np.ndarray:
    """Semantic entities of a class on G': raw-typed entities plus the
    members of the class's molecules (type edges moved to surrogates)."""
    direct = fg.store.index.entities_of_class(int(class_id))
    direct = direct[~fg.is_surrogate(direct)]
    t = fg.tables.get(int(class_id))
    if t is None:
        return direct
    mem, _ = fg.members_of(t.surrogates)
    return np.union1d(direct, mem)


def match_molecules(table, ground_sp: Sequence[tuple[int, int]]
                    ) -> np.ndarray:
    """Molecule-table rows whose object tuple satisfies the given
    (in-SP) ground arms -- one vectorized comparison over AMI rows."""
    mask = np.ones((table.n_molecules,), bool)
    for p, o in ground_sp:
        mask &= table.objects[:, table.col_of(p)] == o
    return np.flatnonzero(mask)


def eval_factorized(fg: FactorizedGraph, q: StarQuery,
                    _mol_rows: np.ndarray | None = None) -> Bindings:
    """Evaluate directly on G' (see module docstring for the split
    between the molecule-table path and the residual-raw fall-back).

    ``_mol_rows`` lets the batched device path inject the molecule-match
    result it computed for a whole query stack in one lowering; host
    callers leave it ``None``.
    """
    table = fg.tables.get(int(q.class_id)) \
        if q.class_id is not None else None
    ground = q.ground_arms
    cand: np.ndarray | None = None
    rest_ground = ground
    if table is not None:
        sp_ground = [(p, o) for p, o in ground
                     if table.col_of(p) is not None]
        rest_ground = [(p, o) for p, o in ground
                       if table.col_of(p) is None]
        # absorbed population: match the molecule table, emit members
        rows = match_molecules(table, sp_ground) \
            if _mol_rows is None else np.asarray(_mol_rows)
        a_subs, _ = fg.members_of(table.surrogates[rows])
        # raw population of the class (incomplete molecules, post-delete
        # decompactions): every arm checked against the residual triples
        b_subs = fg.store.index.entities_of_class(int(q.class_id))
        b_subs = b_subs[~fg.is_surrogate(b_subs)]
        if b_subs.shape[0] == 0:
            # fully-absorbed class (the common case): members of distinct
            # molecules are disjoint, so no dedup sort is needed
            cand = a_subs
        else:
            for p, o in sp_ground:
                if b_subs.shape[0] == 0:
                    break
                b_subs = _intersect(b_subs, _arm_subject_set(fg, p, o))
            cand = np.union1d(a_subs, b_subs)
    elif q.class_id is not None:
        cand = _class_members(fg, q.class_id)
    for p, o in rest_ground:
        if cand is not None and cand.shape[0] == 0:
            break
        cand = _intersect(cand, _arm_subject_set(fg, p, o))
    var_props = q.var_props
    if cand is None:
        if not var_props:
            raise ValueError("star query needs a class or at least one arm")
        s0, _ = _arm_pairs(fg, var_props[0])
        cand = np.unique(s0)
    return _join_vars(cand, var_props, lambda p, c: _arm_pairs(fg, p, c))

"""Asynchronous, atomic, sharded checkpointing with retention + restart.

Layout (one directory per step):

    <root>/step_000100.tmp/          # staging (invisible to restore)
        manifest.json                # treedef paths, shapes, dtypes, step
        <leaf-path>.npy[.zst]        # one file per tree leaf
    <root>/step_000100/              # atomic os.replace on completion

Design points for 1000+ node deployments (single-process here, same
structure):

* **Atomicity** -- a checkpoint is visible iff the final rename happened;
  a crash mid-write leaves only ``.tmp`` garbage that is skipped and
  garbage-collected on the next save.
* **Async** -- ``save()`` snapshots to host RAM (device_get) synchronously
  (bounded by HBM->host bandwidth) and writes to disk on a background
  thread; training continues.  ``wait()`` joins before the next save so at
  most one write is in flight.
* **Sharded** -- each leaf is keyed by its tree path; on a real multi-host
  deployment each host dumps only the shards it owns (addressable_shards)
  with the same manifest; restore re-assembles + re-shards (dist/elastic).
* **Retention** -- keep the newest ``keep`` checkpoints, delete older ones
  after a successful save (never before).
* **Self-describing** -- restore needs only the directory; the manifest
  rebuilds the tree, so elastic restarts can re-shard onto a new mesh.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any

import jax
import numpy as np

try:
    import zstandard
except ImportError:                                   # pragma: no cover
    zstandard = None


def _flatten(tree) -> list[tuple[str, Any]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        key = "/".join(_path_str(p) for p in path)
        out.append((key, leaf))
    return out


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    return str(p)


def latest_step(root: str) -> int | None:
    if not os.path.isdir(root):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(root)
             if d.startswith("step_") and not d.endswith(".tmp")]
    return max(steps) if steps else None


class Checkpointer:
    def __init__(self, root: str, *, keep: int = 3, compress: bool = False,
                 async_write: bool = True):
        self.root = root
        self.keep = keep
        self.compress = compress and zstandard is not None
        self.async_write = async_write
        self._thread: threading.Thread | None = None
        self._error: BaseException | None = None
        os.makedirs(root, exist_ok=True)

    # -- save ----------------------------------------------------------------
    def save(self, state, step: int) -> None:
        self.wait()
        # synchronous device->host snapshot (consistent view of the step)
        host = [(k, np.asarray(jax.device_get(v)))
                for k, v in _flatten(state)]
        if self.async_write:
            self._thread = threading.Thread(
                target=self._write, args=(host, step), daemon=True)
            self._thread.start()
        else:
            self._write(host, step)

    def _write(self, host: list[tuple[str, np.ndarray]], step: int) -> None:
        try:
            tmp = os.path.join(self.root, f"step_{step:06d}.tmp")
            final = os.path.join(self.root, f"step_{step:06d}")
            shutil.rmtree(tmp, ignore_errors=True)
            os.makedirs(tmp)
            manifest = {"step": step, "leaves": []}
            for key, arr in host:
                fname = key.replace("/", "__") + ".npy"
                path = os.path.join(tmp, fname)
                if self.compress:
                    raw = arr.tobytes()
                    with open(path + ".zst", "wb") as f:
                        f.write(zstandard.ZstdCompressor(level=3)
                                .compress(raw))
                else:
                    np.save(path, arr)
                manifest["leaves"].append(
                    {"key": key, "file": fname + (".zst" if self.compress
                                                  else ""),
                     "shape": list(arr.shape), "dtype": str(arr.dtype)})
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
            shutil.rmtree(final, ignore_errors=True)
            os.replace(tmp, final)                    # atomic publish
            self._gc()
        except BaseException as e:                    # surfaced on wait()
            self._error = e

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def _gc(self) -> None:
        steps = sorted(int(d.split("_")[1]) for d in os.listdir(self.root)
                       if d.startswith("step_") and not d.endswith(".tmp"))
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.root, f"step_{s:06d}"),
                          ignore_errors=True)
        for d in os.listdir(self.root):               # crash leftovers
            if d.endswith(".tmp"):
                shutil.rmtree(os.path.join(self.root, d),
                              ignore_errors=True)

    # -- restore ---------------------------------------------------------------
    def restore(self, like, step: int | None = None, shardings=None):
        """Restore into the structure of ``like`` (a state tree or tree of
        ShapeDtypeStructs).  ``shardings``: optional matching tree -- arrays
        are device_put with them (elastic re-shard onto any mesh)."""
        if step is None:
            step = latest_step(self.root)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {self.root}")
        d = os.path.join(self.root, f"step_{step:06d}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        by_key = {m["key"]: m for m in manifest["leaves"]}
        flat, treedef = jax.tree_util.tree_flatten_with_path(like)
        sh_flat = (jax.tree.leaves(shardings) if shardings is not None
                   else [None] * len(flat))
        out = []
        for (path, leaf), sh in zip(flat, sh_flat):
            key = "/".join(_path_str(p) for p in path)
            m = by_key[key]
            p = os.path.join(d, m["file"])
            if m["file"].endswith(".zst"):
                raw = zstandard.ZstdDecompressor().decompress(
                    open(p, "rb").read())
                arr = np.frombuffer(raw, dtype=m["dtype"]).reshape(m["shape"])
            else:
                arr = np.load(p)
            out.append(jax.device_put(arr, sh) if sh is not None
                       else jax.numpy.asarray(arr))
        return jax.tree_util.tree_unflatten(treedef, out), step

"""Pallas TPU kernel: murmur3 row signatures for the FSP group-by.

This is the compute hot-spot of frequent-star-pattern detection at scale:
hashing the (entities x |SP|) object-id matrix into 64-bit signatures
(two uint32 lanes) that the sort/segment group-by consumes.  On a v5e this
is VPU-bound integer work; rows are tiled into VMEM blocks of
``TILE_N x K`` and both hash lanes are produced in one pass (the |SP|
columns are unrolled -- property sets are small, <= 32).

Two entry shapes share one kernel body:

* ``(N, K)``    -- grid ``(N / TILE_N,)``: the single-candidate group-by.
* ``(C, N, K)`` -- grid ``(C, N / TILE_N)``: the candidate-batched sweep.
  The leading grid axis ranges over the C column-mask candidates of one
  ``sweep_candidates`` lowering, so the whole stack hashes in ONE
  ``pallas_call`` instead of C dispatches (or a vmap that re-traces the
  kernel); the padded-row sentinel convention is applied per candidate by
  the caller (``kernels.ops.row_signature``).

Layout rationale: the row dimension maps to (sublanes x lanes) after the
internal reshape; with TILE_N = 1024 the working set is
1024 x K x 4 B <= 128 KiB for K <= 32, far under the ~16 MiB VMEM budget,
letting the pipeline run several blocks deep.  The candidate grid axis
multiplies blocks, not block size, so the VMEM bound is unchanged.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

TILE_N = 1024


def _hash_block(x: jax.Array, k: int) -> jax.Array:
    """(TILE_N, K) uint32 -> (TILE_N, 2) uint32 (hi, lo) murmur3 lanes."""
    h_lo = jnp.zeros((x.shape[0],), jnp.uint32)
    h_hi = jnp.full((x.shape[0],), jnp.uint32(ref._SEED_HI))
    for j in range(k):                           # unrolled: K is small
        h_lo = ref._mm3_step(h_lo, x[:, j])
        h_hi = ref._mm3_step(h_hi, x[:, j] ^ jnp.uint32(0xdeadbeef))
    h_lo = ref._fmix32(h_lo ^ jnp.uint32(k))
    h_hi = ref._fmix32(h_hi ^ jnp.uint32(k))
    return jnp.stack([h_hi, h_lo], axis=1)


def _sig_hash_kernel(x_ref, out_ref, *, k: int):
    out_ref[...] = _hash_block(x_ref[...].astype(jnp.uint32), k)


def _sig_hash_kernel_batched(x_ref, out_ref, *, k: int):
    # block is (1, TILE_N, K): one candidate's tile per grid cell
    out_ref[0] = _hash_block(x_ref[0].astype(jnp.uint32), k)


@functools.partial(jax.jit, static_argnames=("interpret",))
def sig_hash(mat: jax.Array, interpret: bool = True) -> jax.Array:
    """(N, K) int32 -> (N, 2) uint32 row signatures (murmur3, two lanes).

    A ``(C, N, K)`` candidate stack maps to ``(C, N, 2)`` with the
    candidate axis as the leading Pallas grid dimension (one launch).
    """
    if mat.ndim == 3:
        c, n, k = mat.shape
        n_pad = -n % TILE_N
        padded = jnp.pad(mat, ((0, 0), (0, n_pad), (0, 0)))
        grid = (c, padded.shape[1] // TILE_N)
        out = pl.pallas_call(
            functools.partial(_sig_hash_kernel_batched, k=k),
            grid=grid,
            in_specs=[pl.BlockSpec((1, TILE_N, k), lambda ci, i: (ci, i, 0))],
            out_specs=pl.BlockSpec((1, TILE_N, 2), lambda ci, i: (ci, i, 0)),
            out_shape=jax.ShapeDtypeStruct((c, padded.shape[1], 2),
                                           jnp.uint32),
            interpret=interpret,
        )(padded)
        return out[:, :n]
    n, k = mat.shape
    n_pad = -n % TILE_N
    padded = jnp.pad(mat, ((0, n_pad), (0, 0)))
    grid = (padded.shape[0] // TILE_N,)
    out = pl.pallas_call(
        functools.partial(_sig_hash_kernel, k=k),
        grid=grid,
        in_specs=[pl.BlockSpec((TILE_N, k), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((TILE_N, 2), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((padded.shape[0], 2), jnp.uint32),
        interpret=interpret,
    )(padded)
    return out[:n]

"""Pallas TPU kernel: murmur3 row signatures for the FSP group-by.

This is the compute hot-spot of frequent-star-pattern detection at scale:
hashing the (entities x |SP|) object-id matrix into 64-bit signatures
(two uint32 lanes) that the sort/segment group-by consumes.  On a v5e this
is VPU-bound integer work; rows are tiled into VMEM blocks of
``TILE_N x K`` and both hash lanes are produced in one pass (the |SP|
columns are unrolled -- property sets are small, <= 32).

Layout rationale: the row dimension maps to (sublanes x lanes) after the
internal reshape; with TILE_N = 1024 the working set is
1024 x K x 4 B <= 128 KiB for K <= 32, far under the ~16 MiB VMEM budget,
letting the pipeline run several blocks deep.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

TILE_N = 1024


def _sig_hash_kernel(x_ref, out_ref, *, k: int):
    x = x_ref[...].astype(jnp.uint32)            # (TILE_N, K)
    h_lo = jnp.zeros((x.shape[0],), jnp.uint32)
    h_hi = jnp.full((x.shape[0],), jnp.uint32(ref._SEED_HI))
    for j in range(k):                           # unrolled: K is small
        h_lo = ref._mm3_step(h_lo, x[:, j])
        h_hi = ref._mm3_step(h_hi, x[:, j] ^ jnp.uint32(0xdeadbeef))
    h_lo = ref._fmix32(h_lo ^ jnp.uint32(k))
    h_hi = ref._fmix32(h_hi ^ jnp.uint32(k))
    out_ref[...] = jnp.stack([h_hi, h_lo], axis=1)


@functools.partial(jax.jit, static_argnames=("interpret",))
def sig_hash(mat: jax.Array, interpret: bool = True) -> jax.Array:
    """(N, K) int32 -> (N, 2) uint32 row signatures (murmur3, two lanes)."""
    n, k = mat.shape
    n_pad = -n % TILE_N
    padded = jnp.pad(mat, ((0, n_pad), (0, 0)))
    grid = (padded.shape[0] // TILE_N,)
    out = pl.pallas_call(
        functools.partial(_sig_hash_kernel, k=k),
        grid=grid,
        in_specs=[pl.BlockSpec((TILE_N, k), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((TILE_N, 2), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((padded.shape[0], 2), jnp.uint32),
        interpret=interpret,
    )(padded)
    return out[:n]

"""Flash-equivalent chunked attention in pure XLA (jax.lax.scan + custom_vjp).

WHY THIS EXISTS.  On TPU the train/prefill hot spot runs the Pallas flash
kernel (``flash_attention.py``).  The multi-pod dry-run, however, lowers
the XLA path so ``cost_analysis`` reflects the compiled graph -- and the
naive reference materializes the (B, H, T, S) score matrix (7 GB/device
for qwen2 train_4k).  This module is the XLA twin of the flash kernel:
same online-softmax algorithm, O(T * chunk) live memory, hand-written
backward that recomputes probabilities per key-chunk (exactly what the
Pallas backward does from VMEM tiles).  It is also the executable CPU
path, validated against ``ref.mha_ref`` in tests/test_kernels.py.

Supports GQA (grouped einsums -- K/V are never repeated to Hq), causal
masking with history offset (queries occupy the last T slots of the
S-long history), and local windows.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.compat import shard_map

NEG_INF = -1e30


def _pick_chunk(s: int, target: int = 512) -> int:
    """Largest divisor of ``s`` that is <= target (power-of-2 preferred)."""
    c = min(target, s)
    while c > 1 and s % c:
        c -= 1
    return max(c, 1)


def _penalty(t, s, kc, i, causal, window):
    """(T, kc) additive mask penalty (0 = attend, NEG_INF = masked).

    Additive form, not a boolean ``where``: broadcasting a bool mask to the
    (B, Hkv, g, T, kc) score shape materializes a multi-GB pred tensor once
    XLA hoists the loop-invariant masks out of the chunk scan."""
    qpos = jnp.arange(t)[:, None] + (s - t)
    kpos = i * kc + jnp.arange(kc)[None, :]
    pen = jnp.zeros((t, kc), jnp.float32)
    if causal:
        pen = jnp.where(kpos <= qpos, pen, NEG_INF)
    if window is not None:
        pen = jnp.where(kpos > qpos - window, pen, NEG_INF)
    return pen


def _hint(x, spec):
    if spec is None:
        return x
    return jax.lax.with_sharding_constraint(x, spec)


def _fwd(causal, window, sm_scale, kc, group_spec, q, k, v):
    b, hq, t, d = q.shape
    _, hkv, s, _ = k.shape
    g = hq // hkv
    nc = s // kc
    qf = q.astype(jnp.float32).reshape(b, hkv, g, t, d) * sm_scale
    qf = _hint(qf, group_spec)   # pin grouped-head layout (dist/sharding)
    kr = k.reshape(b, hkv, nc, kc, d)
    vr = v.reshape(b, hkv, nc, kc, d)

    def body(carry, i):
        m, l, acc = carry
        kj = jnp.take(kr, i, axis=2).astype(jnp.float32)   # (B,Hkv,kc,D)
        vj = jnp.take(vr, i, axis=2).astype(jnp.float32)
        sc = jnp.einsum("bkgtd,bksd->bkgts", qf, kj)
        sc = sc + _penalty(t, s, kc, i, causal, window)
        # the -0.8*NEG_INF floor keeps exp() at exactly 0 for fully-masked
        # chunks (sc - m_new <= 0.2*NEG_INF) without a boolean mask tensor
        m_new = jnp.maximum(jnp.maximum(m, sc.max(-1)), 0.8 * NEG_INF)
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(sc - m_new[..., None])
        l = l * alpha + p.sum(-1)
        acc = acc * alpha[..., None] + jnp.einsum("bkgts,bksd->bkgtd", p, vj)
        return (m_new, l, acc), None

    m0 = jnp.full((b, hkv, g, t), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, hkv, g, t), jnp.float32)
    a0 = jnp.zeros((b, hkv, g, t, d), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), jnp.arange(nc))
    l = jnp.maximum(l, 1e-30)                     # fully-masked rows -> 0
    out = (acc / l[..., None]).reshape(b, hq, t, d).astype(q.dtype)
    lse = (m + jnp.log(l)).reshape(b, hq, t)
    return out, lse


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1, 2, 3, 4))
def _attn(causal, window, sm_scale, kc, group_spec, q, k, v):
    out, _ = _fwd(causal, window, sm_scale, kc, group_spec, q, k, v)
    return out


def _attn_fwd(causal, window, sm_scale, kc, group_spec, q, k, v):
    out, lse = _fwd(causal, window, sm_scale, kc, group_spec, q, k, v)
    return out, (q, k, v, out, lse)


def _attn_bwd(causal, window, sm_scale, kc, group_spec, res, dout):
    q, k, v, out, lse = res
    b, hq, t, d = q.shape
    _, hkv, s, _ = k.shape
    g = hq // hkv
    nc = s // kc
    qf = _hint(q.astype(jnp.float32).reshape(b, hkv, g, t, d), group_spec)
    dof = _hint(dout.astype(jnp.float32).reshape(b, hkv, g, t, d),
                group_spec)
    lser = lse.reshape(b, hkv, g, t)
    # delta_i = sum_d dout_i * out_i  (rowwise, standard flash-bwd trick)
    delta = jnp.sum(dof * out.astype(jnp.float32).reshape(qf.shape), -1)
    kr = k.reshape(b, hkv, nc, kc, d)
    vr = v.reshape(b, hkv, nc, kc, d)

    def body(dq, i):
        kj = jnp.take(kr, i, axis=2).astype(jnp.float32)
        vj = jnp.take(vr, i, axis=2).astype(jnp.float32)
        sc = jnp.einsum("bkgtd,bksd->bkgts", qf, kj) * sm_scale
        sc = sc + _penalty(t, s, kc, i, causal, window)
        p = jnp.exp(sc - lser[..., None])   # masked: exp(~NEG_INF) == 0
        dv_j = jnp.einsum("bkgts,bkgtd->bksd", p, dof)
        dp = jnp.einsum("bkgtd,bksd->bkgts", dof, vj)
        ds = p * (dp - delta[..., None])                    # d/d(sc)
        dq = dq + jnp.einsum("bkgts,bksd->bkgtd", ds, kj)
        dk_j = jnp.einsum("bkgts,bkgtd->bksd", ds, qf)
        return dq, (dk_j, dv_j)

    dq0 = jnp.zeros((b, hkv, g, t, d), jnp.float32)
    dq, (dks, dvs) = jax.lax.scan(body, dq0, jnp.arange(nc))
    dq = (dq * sm_scale).reshape(b, hq, t, d).astype(q.dtype)
    dk = (dks * sm_scale).transpose(1, 2, 0, 3, 4) \
        .reshape(b, hkv, s, d).astype(k.dtype)
    dv = dvs.transpose(1, 2, 0, 3, 4).reshape(b, hkv, s, d).astype(v.dtype)
    return dq, dk, dv


_attn.defvjp(_attn_fwd, _attn_bwd)


def chunked_attention(q, k, v, *, causal: bool = True,
                      window: int | None = None,
                      sm_scale: float | None = None,
                      chunk: int = 512, group_spec=None):
    """GQA attention, O(T x chunk) memory.  q: (B,Hq,T,D); k,v: (B,Hkv,S,D).

    ``group_spec``: PartitionSpec for the internal (B, Hkv, G, T, D)
    grouped-q layout (hashable -> a static custom_vjp arg)."""
    if sm_scale is None:
        sm_scale = 1.0 / (q.shape[-1] ** 0.5)
    kc = _pick_chunk(k.shape[2], chunk)
    return _attn(causal, window, float(sm_scale), kc, group_spec, q, k, v)


def decode_attention(q, k, v, bias, *, chunk: int = 1024,
                     sm_scale: float | None = None):
    """Flash-decode: one query against an S-long cache, online softmax
    over key chunks.  Replaces the naive decode path that materializes
    (B, Hkv, G, S) f32 logits/probs (qwen3 decode_32k: 4.3 GB per layer
    per token -- §Perf).

    q: (B, Hkv, G, hd); k, v: (B, Hkv, S, hd); bias: (B, S) additive
    (0 = attend, NEG_INF = masked ring-buffer slot).  Returns
    (B, Hkv, G, hd) in q's dtype; no grad path (serving only).
    """
    b, hkv, g, d = q.shape
    s = k.shape[2]
    if sm_scale is None:
        sm_scale = 1.0 / (d ** 0.5)
    kc = _pick_chunk(s, chunk)
    nc = s // kc
    qf = q.astype(jnp.float32) * sm_scale
    kr = k.reshape(b, hkv, nc, kc, d)
    vr = v.reshape(b, hkv, nc, kc, d)
    br = bias.astype(jnp.float32).reshape(b, nc, kc)

    def body(carry, i):
        m, l, acc = carry
        kj = jnp.take(kr, i, axis=2).astype(jnp.float32)
        vj = jnp.take(vr, i, axis=2).astype(jnp.float32)
        sc = jnp.einsum("bkgd,bksd->bkgs", qf, kj) \
            + jnp.take(br, i, axis=1)[:, None, None, :]
        m_new = jnp.maximum(jnp.maximum(m, sc.max(-1)), 0.8 * NEG_INF)
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(sc - m_new[..., None])
        l = l * alpha + p.sum(-1)
        acc = acc * alpha[..., None] + jnp.einsum("bkgs,bksd->bkgd", p, vj)
        return (m_new, l, acc), None

    m0 = jnp.full((b, hkv, g), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, hkv, g), jnp.float32)
    a0 = jnp.zeros((b, hkv, g, d), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), jnp.arange(nc))
    l = jnp.maximum(l, 1e-30)
    return (acc / l[..., None]).astype(q.dtype)


def decode_attention_sharded(q, k, v, bias, *, mesh, seq_axis: str = "model",
                             q_spec=None, kv_spec=None, bias_spec=None,
                             sm_scale: float | None = None):
    """Flash-decode over a SEQUENCE-SHARDED KV cache (shard_map).

    Each device computes the online softmax over its local S/TP keys, then
    three tiny collectives combine the per-shard (m, l, acc) statistics:
    m* = pmax(m); l* = psum(l * exp(m - m*)); acc* = psum(acc * exp(m-m*)).
    Chunking the sharded S inside one jit instead makes GSPMD reshard the
    cache every chunk (qwen3 decode_32k: +5.2 s/token of collectives --
    §Perf iteration log, refuted-hypothesis entry).

    q: (B, Hkv, G, hd) replicated over seq_axis; k, v: (B, Hkv, S, hd)
    sharded over seq_axis on dim 2; bias: (B, S) additive mask.
    """
    from jax.sharding import PartitionSpec as P

    if sm_scale is None:
        sm_scale = 1.0 / (q.shape[-1] ** 0.5)

    def body(ql, kl, vl, bl):
        qf = ql.astype(jnp.float32) * sm_scale
        sc = jnp.einsum("bkgd,bksd->bkgs", qf, kl.astype(jnp.float32)) \
            + bl.astype(jnp.float32)[:, None, None, :]
        m = jnp.maximum(sc.max(-1), 0.8 * NEG_INF)       # (B,Hkv,G)
        p = jnp.exp(sc - m[..., None])
        l = p.sum(-1)
        acc = jnp.einsum("bkgs,bksd->bkgd", p, vl.astype(jnp.float32))
        m_g = jax.lax.pmax(m, seq_axis)
        corr = jnp.exp(m - m_g)
        l_g = jax.lax.psum(l * corr, seq_axis)
        acc_g = jax.lax.psum(acc * corr[..., None], seq_axis)
        return (acc_g / jnp.maximum(l_g, 1e-30)[..., None]).astype(ql.dtype)

    qs = q_spec if q_spec is not None else P(None, None, None, None)
    ks = kv_spec if kv_spec is not None else P(None, None, seq_axis, None)
    bs = bias_spec if bias_spec is not None else P(None, seq_axis)
    return shard_map(body, mesh=mesh, in_specs=(qs, ks, ks, bs),
                     out_specs=qs)(q, k, v, bias)

"""jit'd dispatch wrappers over the Pallas kernels with pure-jnp fallbacks.

Selection policy:
  * On a TPU runtime the compiled Pallas kernels are used directly.
  * On CPU (this container, CI) kernels run in ``interpret=True`` mode for
    correctness validation; callers that feed the *dry-run* lowering use the
    XLA reference path (``impl="xla"``) so cost analysis reflects the
    XLA-compiled graph rather than the interpreter scaffolding.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import ref
from .chunked_attention import chunked_attention as _chunked
from .flash_attention import flash_attention as _flash
from .linear_scan import linear_scan as _linear_scan
from .seg_count import seg_boundaries as _seg_boundaries
from .sig_hash import sig_hash as _sig_hash


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


# -- FSP group-by -----------------------------------------------------------

# all-ones signature reserved for masked-out rows: every invalid row
# collapses into one sentinel segment the callers subtract back out
SIG_SENTINEL = 0xFFFFFFFF


def row_signature(mat, valid=None, use_kernel: bool = True):
    """(N, K) int -> (N, 2) uint32 signature lanes (hi, lo).

    A candidate-batched ``(C, N, K)`` stack maps to ``(C, N, 2)``: the
    kernel path runs one launch with C as a Pallas grid axis, and the
    sentinel convention below is applied per candidate.

    ``valid``: optional bool mask -- ``(N,)`` (shared across candidates)
    or ``(C, N)``; rows with ``valid == False`` (bucket/shard padding)
    receive the reserved sentinel signature so group-by consumers can
    discount them with one segment subtraction.  Masking happens here --
    at the op boundary -- so every caller (single-device AMI, the
    bucketed sweep, the shard_map collective schedule) shares one
    sentinel convention instead of hand-rolling it.
    """
    if mat.ndim not in (2, 3):
        raise ValueError(f"expected (N, K) or (C, N, K) matrix, "
                         f"got {mat.shape}")
    if use_kernel:
        sig = _sig_hash(mat, interpret=_interpret())
    else:
        sig = ref.row_signature_ref(mat)
    if valid is not None:
        # (N,) -> (N, 1) and (C, N) -> (C, N, 1) both broadcast against
        # (..., N, 2) with per-candidate alignment
        sig = jnp.where(valid[..., None], sig, jnp.uint32(SIG_SENTINEL))
    return sig


def seg_boundaries(sig_sorted, use_kernel: bool = True):
    """Sorted (N, 2) sigs -> ((N,) boundaries, () segment count).

    Batched ``(C, N, 2)`` (each candidate sorted along its own row axis)
    -> ``((C, N) boundaries, (C,) counts)``.
    """
    if use_kernel:
        return _seg_boundaries(sig_sorted, interpret=_interpret())
    b = ref.seg_boundaries_ref(sig_sorted)
    return b, b.sum(axis=-1)


def sort_signatures(sig):
    """Lexicographic sort of (..., N, 2) uint32 signatures along the row
    axis; returns (sorted, order).  Batched stacks sort per candidate.

    Two uint32 lanes replace one uint64 key (TPU-friendly: no 64-bit lanes);
    jnp.lexsort keys are last-key-primary.
    """
    order = jnp.lexsort((sig[..., 1], sig[..., 0]), axis=-1)
    return jnp.take_along_axis(sig, order[..., None], axis=-2), order


# -- attention / recurrence --------------------------------------------------

def attention(q, k, v, *, causal: bool = True, window: int | None = None,
              sm_scale: float | None = None, impl: str = "xla",
              group_spec=None, **tiles):
    """GQA attention dispatch.

    impl: xla (flash-equivalent chunked scan above 1k keys, naive below --
    the dry-run lowers this path so cost analysis reflects what XLA would
    run) | xla_naive | pallas | pallas_interpret (TPU kernel).
    """
    if impl == "xla":
        if k.shape[2] > 1024:
            return _chunked(q, k, v, causal=causal, window=window,
                            sm_scale=sm_scale, group_spec=group_spec)
        return ref.mha_ref(q, k, v, causal=causal, window=window,
                           sm_scale=sm_scale)
    if impl == "xla_naive":
        return ref.mha_ref(q, k, v, causal=causal, window=window,
                           sm_scale=sm_scale)
    if impl == "xla_chunked":
        return _chunked(q, k, v, causal=causal, window=window,
                        sm_scale=sm_scale, group_spec=group_spec)
    interpret = impl == "pallas_interpret" or _interpret()
    return _flash(q, k, v, causal=causal, window=window, sm_scale=sm_scale,
                  interpret=interpret, **tiles)


def linear_scan(x, a, h0=None, *, impl: str = "xla", **tiles):
    """Diagonal linear recurrence dispatch; returns (states, final)."""
    if impl == "xla":
        return ref.linear_scan_ref(x, a, h0)
    interpret = impl == "pallas_interpret" or _interpret()
    return _linear_scan(x, a, h0, interpret=interpret, **tiles)

"""Pallas TPU kernel: GQA flash attention (online softmax, blocked).

Grid is ``(B, Hq, nQ, nKV)`` with the KV dimension sequential ("arbitrary"
semantics): running max ``m``, denominator ``l`` and the output accumulator
live in VMEM scratch across KV steps (the classic Mosaic flash pattern).
Query/key blocks are MXU-aligned (TQ, TKV multiples of 128 for real shapes;
tests sweep smaller interpret-mode shapes).

GQA is expressed in the BlockSpec index maps: the key/value block for query
head ``h`` is ``h // (Hq // Hkv)`` -- no repeat/materialization of KV heads.

Causal + sliding-window masking is applied inside the block; the wrapper
offsets query positions by ``S - T`` so the same kernel serves train
(S == T), prefill, and chunked decode.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_TQ = 128
DEFAULT_TKV = 128
_NEG = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  sm_scale: float, causal: bool, window: int | None,
                  q_offset: int, n_kv: int, tq: int, tkv: int):
    ik = pl.program_id(3)
    iq = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)            # (TQ, D)
    k = k_ref[0, 0].astype(jnp.float32)            # (TKV, D)
    v = v_ref[0, 0].astype(jnp.float32)            # (TKV, D)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * sm_scale

    qpos = iq * tq + jax.lax.broadcasted_iota(jnp.int32, (tq, tkv), 0) \
        + q_offset
    kpos = ik * tkv + jax.lax.broadcasted_iota(jnp.int32, (tq, tkv), 1)
    mask = jnp.ones((tq, tkv), jnp.bool_)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window

    s_masked = jnp.where(mask, s, _NEG)
    m_prev = m_ref[...]
    l_prev = l_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s_masked, axis=1))
    p = jnp.where(mask, jnp.exp(s - m_new[:, None]), 0.0)
    corr = jnp.exp(m_prev - m_new)
    l_new = l_prev * corr + jnp.sum(p, axis=1)
    acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(ik == n_kv - 1)
    def _finalize():
        l = l_ref[...]
        safe = jnp.where(l > 0.0, l, 1.0)
        o_ref[0, 0] = (acc_ref[...] / safe[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "causal", "sm_scale", "window", "tq", "tkv", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True,
                    sm_scale: float | None = None, window: int | None = None,
                    tq: int = DEFAULT_TQ, tkv: int = DEFAULT_TKV,
                    interpret: bool = True):
    """q: (B, Hq, T, D); k, v: (B, Hkv, S, D) -> (B, Hq, T, D)."""
    b, hq, t, d = q.shape
    _, hkv, s, _ = k.shape
    assert hq % hkv == 0
    group = hq // hkv
    if sm_scale is None:
        sm_scale = 1.0 / (d ** 0.5)
    tq = min(tq, t)
    tkv = min(tkv, s)
    t_pad = -t % tq
    s_pad = -s % tkv
    qp = jnp.pad(q, ((0, 0), (0, 0), (0, t_pad), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, s_pad), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, s_pad), (0, 0)))
    n_q = qp.shape[2] // tq
    n_kv = kp.shape[2] // tkv
    grid = (b, hq, n_q, n_kv)
    kernel = functools.partial(
        _flash_kernel, sm_scale=sm_scale, causal=causal, window=window,
        q_offset=s - t, n_kv=n_kv, tq=tq, tkv=tkv)
    try:
        params = pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary"))
    except Exception:  # pragma: no cover - older pltpu naming
        params = None
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, tq, d), lambda bi, hi, qi, ki: (bi, hi, qi, 0)),
            pl.BlockSpec((1, 1, tkv, d),
                         lambda bi, hi, qi, ki, g=group: (bi, hi // g, ki, 0)),
            pl.BlockSpec((1, 1, tkv, d),
                         lambda bi, hi, qi, ki, g=group: (bi, hi // g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, tq, d),
                               lambda bi, hi, qi, ki: (bi, hi, qi, 0)),
        out_shape=jax.ShapeDtypeStruct(qp.shape, q.dtype),
        scratch_shapes=[pltpu.VMEM((tq,), jnp.float32),
                        pltpu.VMEM((tq,), jnp.float32),
                        pltpu.VMEM((tq, d), jnp.float32)],
        compiler_params=params,
        interpret=interpret,
    )(qp, kp, vp)
    return out[:, :, :t]

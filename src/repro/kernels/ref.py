"""Pure-jnp oracles for every Pallas kernel in this package.

Each ``*_ref`` function is the numerical ground truth the kernels are tested
against (tests/test_kernels.py sweeps shapes/dtypes and asserts allclose).
The signature-hash reference is bit-exact (integer math); attention/scan
references are float references with dtype-appropriate tolerances.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# murmur3-style row signatures (FSP group-by hot spot)
# ---------------------------------------------------------------------------

# plain python ints (cast at trace time inside the kernel body -- jnp-array
# module constants would be "captured consts", which pallas_call rejects)
_C1 = 0xcc9e2d51
_C2 = 0x1b873593
_FM1 = 0x85ebca6b
_FM2 = 0xc2b2ae35
_SEED_HI = 0x9e3779b9


def _rotl32(x, r: int):
    return (x << r) | (x >> (32 - r))


def _fmix32(h):
    h = h ^ (h >> 16)
    h = h * jnp.uint32(_FM1)
    h = h ^ (h >> 13)
    h = h * jnp.uint32(_FM2)
    return h ^ (h >> 16)


def _mm3_step(h, k):
    k = k * jnp.uint32(_C1)
    k = _rotl32(k, 15)
    k = k * jnp.uint32(_C2)
    h = h ^ k
    h = _rotl32(h, 13)
    return h * jnp.uint32(5) + jnp.uint32(0xe6546b64)


def row_signature_ref(mat: jax.Array) -> jax.Array:
    """(..., N, K) int32 -> (..., N, 2) uint32 murmur3 row hashes.

    Lane 0 is seeded with 0, lane 1 with the golden ratio; together they
    form a 64-bit signature whose collision probability is ~N^2/2^64.
    Leading batch dimensions (the candidate axis of a batched sweep) hash
    independently with identical per-row results.
    """
    x = mat.astype(jnp.uint32)
    k = x.shape[-1]
    h_lo = jnp.zeros(x.shape[:-1], jnp.uint32)
    h_hi = jnp.full(x.shape[:-1], jnp.uint32(_SEED_HI))
    for j in range(k):
        h_lo = _mm3_step(h_lo, x[..., j])
        h_hi = _mm3_step(h_hi, x[..., j] ^ jnp.uint32(0xdeadbeef))
    h_lo = _fmix32(h_lo ^ jnp.uint32(k))
    h_hi = _fmix32(h_hi ^ jnp.uint32(k))
    return jnp.stack([h_hi, h_lo], axis=-1)


def seg_boundaries_ref(sig_sorted: jax.Array) -> jax.Array:
    """(..., N, 2) sorted signatures -> (..., N) int32; 1 at segment starts.

    Each leading-batch slice (candidate) gets its own always-set first
    boundary, matching the per-candidate shift of the Pallas kernel.
    """
    diff = jnp.any(sig_sorted[..., 1:, :] != sig_sorted[..., :-1, :], axis=-1)
    first = jnp.ones(sig_sorted.shape[:-2] + (1,), jnp.int32)
    return jnp.concatenate([first, diff.astype(jnp.int32)], axis=-1)


# ---------------------------------------------------------------------------
# GQA flash attention (prefill/train hot spot)
# ---------------------------------------------------------------------------

def mha_ref(q, k, v, causal: bool = True, sm_scale: float | None = None,
            window: int | None = None):
    """Reference grouped-query attention.

    q: (B, Hq, T, D); k, v: (B, Hkv, S, D) with Hq % Hkv == 0.
    ``window``: optional local-attention window (RG-LRU hybrid blocks).
    """
    b, hq, t, d = q.shape
    _, hkv, s, _ = k.shape
    group = hq // hkv
    if sm_scale is None:
        sm_scale = 1.0 / (d ** 0.5)
    kx = jnp.repeat(k, group, axis=1)
    vx = jnp.repeat(v, group, axis=1)
    logits = jnp.einsum("bhtd,bhsd->bhts", q.astype(jnp.float32),
                        kx.astype(jnp.float32)) * sm_scale
    # positions: queries occupy the last t slots of the s-long history
    qpos = jnp.arange(t)[:, None] + (s - t)
    kpos = jnp.arange(s)[None, :]
    mask = jnp.ones((t, s), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    logits = jnp.where(mask[None, None], logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhts,bhsd->bhtd", probs, vx.astype(jnp.float32))
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# diagonal linear recurrence (mamba2 SSD / RG-LRU hot spot)
# ---------------------------------------------------------------------------

def linear_scan_ref(x, a, h0=None):
    """h_t = a_t * h_{t-1} + x_t  over axis 1.

    x, a: (B, T, D); h0: (B, D) initial state.  Returns (h_all, h_last):
    (B, T, D) states and the (B, D) final state.  Computed in float32.
    """
    xf = x.astype(jnp.float32)
    af = a.astype(jnp.float32)
    b, t, d = x.shape
    if h0 is None:
        h0 = jnp.zeros((b, d), jnp.float32)

    def step(h, xa):
        xt, at = xa
        h = at * h + xt
        return h, h

    h_last, hs = jax.lax.scan(step, h0.astype(jnp.float32),
                              (xf.swapaxes(0, 1), af.swapaxes(0, 1)))
    return hs.swapaxes(0, 1).astype(x.dtype), h_last

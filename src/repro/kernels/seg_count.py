"""Pallas TPU kernel: segment boundaries over sorted signatures.

After sorting the (N, 2) uint32 signatures produced by ``sig_hash``, the
group-by reduces to marking rows that differ from their predecessor.  AMI
(Def. 4.7) is the sum of the boundary vector; per-segment lengths give the
class multiplicities (Def. 4.5).

The kernel is a blocked elementwise compare between the signature block and
the one-row-shifted block (the wrapper materializes the shift, so no
cross-block halo exchange is needed); each VMEM block also emits its partial
boundary count so AMI can be accumulated without re-reading HBM.

A ``(C, N, 2)`` per-candidate-sorted stack runs under grid ``(C, N / TILE_N)``
-- the candidate axis of one ``sweep_candidates`` lowering is a Pallas grid
dimension, and the first-row-always-differs shift is materialized per
candidate, so every candidate keeps its own segment count (and its own
padded-sentinel segment, which the caller subtracts).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE_N = 2048


def _seg_kernel(cur_ref, prev_ref, bound_ref, partial_ref):
    cur = cur_ref[...]
    prev = prev_ref[...]
    diff = jnp.any(cur != prev, axis=1).astype(jnp.int32)
    bound_ref[...] = diff
    partial_ref[...] = jnp.sum(diff, keepdims=True)


def _seg_kernel_batched(cur_ref, prev_ref, bound_ref, partial_ref):
    # block is (1, TILE_N, 2): one candidate's tile per grid cell
    diff = jnp.any(cur_ref[0] != prev_ref[0], axis=1).astype(jnp.int32)
    bound_ref[0] = diff
    partial_ref[0] = jnp.sum(diff, keepdims=True)


@functools.partial(jax.jit, static_argnames=("interpret",))
def seg_boundaries(sig_sorted: jax.Array, interpret: bool = True
                   ) -> tuple[jax.Array, jax.Array]:
    """(N, 2) sorted sigs -> ((N,) int32 boundaries, () int32 n_segments).

    A ``(C, N, 2)`` stack (each candidate sorted along its own row axis)
    maps to ``((C, N) boundaries, (C,) counts)`` in one launch.
    """
    if sig_sorted.ndim == 3:
        c, n, _ = sig_sorted.shape
        prev = jnp.concatenate([~sig_sorted[:, :1], sig_sorted[:, :-1]],
                               axis=1)
        n_pad = -n % TILE_N
        cur_p = jnp.pad(sig_sorted, ((0, 0), (0, n_pad), (0, 0)))
        prev_p = jnp.pad(prev, ((0, 0), (0, n_pad), (0, 0)))
        if n_pad:
            prev_p = prev_p.at[:, n:].set(cur_p[:, n:])
        grid = (c, cur_p.shape[1] // TILE_N)
        bounds, partials = pl.pallas_call(
            _seg_kernel_batched,
            grid=grid,
            in_specs=[pl.BlockSpec((1, TILE_N, 2), lambda ci, i: (ci, i, 0)),
                      pl.BlockSpec((1, TILE_N, 2), lambda ci, i: (ci, i, 0))],
            out_specs=[pl.BlockSpec((1, TILE_N), lambda ci, i: (ci, i)),
                       pl.BlockSpec((1, 1), lambda ci, i: (ci, i))],
            out_shape=[jax.ShapeDtypeStruct((c, cur_p.shape[1]), jnp.int32),
                       jax.ShapeDtypeStruct((c, grid[1]), jnp.int32)],
            interpret=interpret,
        )(cur_p, prev_p)
        return bounds[:, :n], partials.sum(axis=1)
    n = sig_sorted.shape[0]
    # prev[i] = sig[i-1]; row 0 compares against ~sig[0] so it always differs
    prev = jnp.concatenate([~sig_sorted[:1], sig_sorted[:-1]], axis=0)
    n_pad = -n % TILE_N
    cur_p = jnp.pad(sig_sorted, ((0, n_pad), (0, 0)))
    # pad prev with the same values as cur so padded rows never count
    prev_p = jnp.pad(prev, ((0, n_pad), (0, 0)))
    if n_pad:
        cur_tail = cur_p[n:]
        prev_p = prev_p.at[n:].set(cur_tail)
    grid = (cur_p.shape[0] // TILE_N,)
    bounds, partials = pl.pallas_call(
        _seg_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((TILE_N, 2), lambda i: (i, 0)),
                  pl.BlockSpec((TILE_N, 2), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((TILE_N,), lambda i: (i,)),
                   pl.BlockSpec((1,), lambda i: (i,))],
        out_shape=[jax.ShapeDtypeStruct((cur_p.shape[0],), jnp.int32),
                   jax.ShapeDtypeStruct((grid[0],), jnp.int32)],
        interpret=interpret,
    )(cur_p, prev_p)
    return bounds[:n], partials.sum()

"""Pallas TPU kernel: blocked diagonal linear recurrence.

Computes ``h_t = a_t * h_{t-1} + x_t`` over the time axis -- the state
update shared by mamba2's SSD (scalar-per-head decay broadcast over the
(d_head x d_state) state, flattened into D) and recurrentgemma's RG-LRU
(per-channel gate).

Within a VMEM time-block the recurrence is evaluated with an associative
prefix scan (log-depth on the VPU); the cross-block state is carried in
VMEM scratch across the sequential time grid dimension:

  combine((a_l, x_l), (a_r, x_r)) = (a_l * a_r, a_r * x_l + x_r)
  h_block = A_prefix * h_carry + X_prefix
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_TT = 256


def _scan_kernel(x_ref, a_ref, h0_ref, h_ref, carry_ref, *, n_t: int):
    it = pl.program_id(1)

    @pl.when(it == 0)
    def _init():
        carry_ref[...] = h0_ref[0].astype(jnp.float32)

    x = x_ref[0].astype(jnp.float32)        # (TT, D)
    a = a_ref[0].astype(jnp.float32)        # (TT, D)

    def combine(l, r):
        al, xl = l
        ar, xr = r
        return al * ar, ar * xl + xr

    a_pre, x_pre = jax.lax.associative_scan(combine, (a, x), axis=0)
    h = a_pre * carry_ref[...][None, :] + x_pre
    h_ref[0] = h.astype(h_ref.dtype)
    carry_ref[...] = h[-1]


@functools.partial(jax.jit, static_argnames=("tt", "interpret"))
def linear_scan(x, a, h0=None, *, tt: int = DEFAULT_TT,
                interpret: bool = True):
    """x, a: (B, T, D); h0: (B, D) -> ((B, T, D) states, (B, D) final)."""
    b, t, d = x.shape
    if h0 is None:
        h0 = jnp.zeros((b, d), x.dtype)
    tt = min(tt, t)
    t_pad = -t % tt
    # pad with a=1, x=0 (identity elements) so padding never alters state
    xp = jnp.pad(x, ((0, 0), (0, t_pad), (0, 0)))
    ap = jnp.pad(a, ((0, 0), (0, t_pad), (0, 0)), constant_values=1)
    n_t = xp.shape[1] // tt
    grid = (b, n_t)
    try:
        params = pltpu.CompilerParams(
            dimension_semantics=("parallel", "arbitrary"))
    except Exception:  # pragma: no cover
        params = None
    hs = pl.pallas_call(
        functools.partial(_scan_kernel, n_t=n_t),
        grid=grid,
        in_specs=[pl.BlockSpec((1, tt, d), lambda bi, ti: (bi, ti, 0)),
                  pl.BlockSpec((1, tt, d), lambda bi, ti: (bi, ti, 0)),
                  pl.BlockSpec((1, d), lambda bi, ti: (bi, 0))],
        out_specs=pl.BlockSpec((1, tt, d), lambda bi, ti: (bi, ti, 0)),
        out_shape=jax.ShapeDtypeStruct(xp.shape, x.dtype),
        scratch_shapes=[pltpu.VMEM((d,), jnp.float32)],
        compiler_params=params,
        interpret=interpret,
    )(xp, ap, h0)
    hs = hs[:, :t]
    return hs, hs[:, -1].astype(x.dtype)

"""Pallas TPU kernels for the framework's compute hot-spots.

Each kernel module pairs with a pure-jnp oracle in ``ref.py``; ``ops.py``
holds the jit'd dispatch wrappers.  Kernels target TPU (BlockSpec VMEM
tiling) and are validated on CPU via ``interpret=True``.
"""

"""Paper Table 4: #Edges(SP, C, G) for every property set A1-A10 over the
graded datasets.  Validates the paper's ordering claims: A5 minimal among
Observation sets, A8 minimal among Measurement sets, A4 maximal."""
from __future__ import annotations

from repro.api import get_backend
from repro.data.synthetic import PROPERTY_SETS, property_set_ids

from .common import DATASETS, dataset, report


def run(fast: bool = False) -> list[dict]:
    rows = []
    values: dict[str, dict[str, int]] = {}
    backend = get_backend("host")
    for ds in DATASETS:
        store = dataset(ds)
        for sid in PROPERTY_SETS:
            cid, pids = property_set_ids(store, sid)
            n_s = len(store.class_properties(cid))
            am = store.class_stats(cid).n_instances
            res = backend.evaluate(store, cid, tuple(pids), n_s, am)
            values.setdefault(sid, {})[ds] = res.edges
    for sid in PROPERTY_SETS:
        rows.append({"SID": sid, **values[sid]})
    # paper's ordering claims
    for ds in DATASETS:
        obs = {s: values[s][ds] for s in
               ("A1", "A2", "A3", "A4", "A5", "A6", "A7")}
        meas = {s: values[s][ds] for s in ("A8", "A9", "A10")}
        assert min(obs, key=obs.get) == "A5", (ds, obs)
        assert max(obs, key=obs.get) == "A4", (ds, obs)
        assert min(meas, key=meas.get) == "A8", (ds, meas)
    report("table4_formula_values", rows)
    return rows


if __name__ == "__main__":
    run()

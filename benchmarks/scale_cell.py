"""One (shape x scale x tier) cell of the scale grid, in its OWN process.

``ru_maxrss`` is per-process and monotone -- the only way to attribute a
peak-RSS number to a cell is to give the cell a fresh process.  The
parent (``benchmarks.run --scale``) launches this module once per
(shape, n_triples, tier), reads the JSON document printed on the last
stdout line, and cross-checks detect/query digests between the two
tiers of every cell.

What one cell does:

1. generate the workload shape at the target scale (vectorized);
2. on the compressed tier: re-host the graph on the bit-packed
   substrate, drop the plain store, and collect -- from here on the
   uncompressed triple arrays exist only transiently inside decodes;
3. detect (cold + warm) through the standard ``Compactor`` pipeline --
   the compressed tier streams classes (``stream=True``) so resident
   decodes never accumulate past one class's working set;
4. answer a star-query workload (molecule lookups + var arms) twice,
   digesting the binding sets;
5. optionally run the online-soak twin comparison (``--twin N``):
   N same-shape insert batches through an ``OnlineCompactionService``
   vs its ``auto_redetect=False`` twin, reporting the final edge
   advantage of recompaction (ROADMAP item 4 leftover, per cell);
6. print a one-line JSON report: times, digests, substrate bytes,
   bytes-per-triple, decode counters, ``ru_maxrss``.

Deterministic substrate accounting (``substrate_nbytes``) carries the
compression gate; ``ru_maxrss`` is recorded as the honest whole-process
context (it includes generation, which necessarily materializes
uncompressed arrays before handing them to the compressor).
"""
from __future__ import annotations

import argparse
import gc
import hashlib
import json
import resource
import sys
import time

import numpy as np


def _rss_kb() -> int:
    # linux reports ru_maxrss in KiB
    return int(resource.getrusage(resource.RUSAGE_SELF).ru_maxrss)


def _build_queries(fg, store, max_lookups: int = 24, max_var: int = 8):
    """Star workload off the compacted form: all-ground molecule lookups
    + var-arm scans per factorized class; classes that did not factorize
    (adversarial shape) get index-derived ground+var probes instead."""
    from repro.query import StarQuery

    queries = []
    for cid, t in sorted(fg.tables.items()):
        for row in t.objects[:max_lookups]:
            queries.append(StarQuery(
                arms=tuple((int(p), int(o))
                           for p, o in zip(t.props, row)),
                class_id=cid))
        for row in t.objects[:max_var]:
            queries.append(StarQuery(
                arms=((int(t.props[0]), int(row[0])),
                      (int(t.props[-1]), None)),
                class_id=cid))
    if not queries:                      # nothing factorized: raw probes
        idx = store.index
        for cid in idx.classes().tolist()[:4]:
            props = idx.class_properties(cid)
            if props.shape[0] < 2:
                continue
            p0, p1 = int(props[0]), int(props[-1])
            objs = idx.pred_objects_sorted(p0)
            for o in objs[:: max(objs.shape[0] // 8, 1)][:8]:
                queries.append(StarQuery(
                    arms=((p0, int(o)), (p1, None)), class_id=int(cid)))
    return queries


def _digest(bindings) -> str:
    h = hashlib.sha1()
    for b in bindings:
        h.update(b.canonical().tobytes())
    return h.hexdigest()[:16]


def _twin_soak(snapshot, shape: str, n_batches: int, seed: int) -> dict:
    """Per-cell no-recompaction-twin comparison: the same same-shape
    insert stream through a recompacting service and a twin that only
    applies -- the final G' edge gap is what re-detection bought."""
    from repro.data.synthetic import WorkloadSpec, generate_workload
    from repro.online import OnlineCompactionService

    svc = OnlineCompactionService(snapshot, min_predicted_savings=1)
    twin = OnlineCompactionService(snapshot, auto_redetect=False)
    for b in range(n_batches):
        batch = generate_workload(WorkloadSpec(
            shape=shape, n_triples=2_000, seed=seed + 101 + b))
        # remap entity terms (subjects, and objects that are themselves
        # subjects) behind a per-batch prefix: the inserts become NEW
        # entities of the EXISTING classes/vocabulary instead of
        # colliding with same-named entities of the base graph
        subs = set(batch.spo[:, 0].tolist())
        t = batch.dict.term
        trips = []
        for s, p, o in batch.spo[:1_000].tolist():
            trips.append((f"b{b}/{t(s)}", t(p),
                          f"b{b}/{t(o)}" if o in subs else t(o)))
        svc.submit(inserts=trips)
        twin.submit(inserts=trips)
    svc.drain()
    twin.drain()
    assert svc.snapshot.digest() == twin.snapshot.digest(), \
        "twin semantic divergence"
    return {
        "batches": n_batches,
        "edges": int(svc.snapshot.n_triples),
        "edges_twin": int(twin.snapshot.n_triples),
        "edge_advantage": int(twin.snapshot.n_triples
                              - svc.snapshot.n_triples),
        "swaps": svc.swap_count,
    }


def run_cell(shape: str, n_triples: int, tier: str, backend: str,
             seed: int, twin: int) -> dict:
    from repro.api import Compactor
    from repro.core import sweep as core_sweep
    from repro.core.compress import DECODE_STATS, compress_store
    from repro.data.synthetic import WorkloadSpec, generate_workload
    from repro.query import QueryEngine

    t0 = time.perf_counter()
    store = generate_workload(WorkloadSpec(
        shape=shape, n_triples=n_triples, seed=seed))
    gen_ms = (time.perf_counter() - t0) * 1e3
    n = store.n_triples
    plain_bytes = store.substrate_nbytes()

    if tier == "compressed":
        t0 = time.perf_counter()
        store = compress_store(store)
        store.release_decoded()
        compress_ms = (time.perf_counter() - t0) * 1e3
        gc.collect()
    else:
        compress_ms = 0.0
    sub_bytes = store.substrate_nbytes()

    stream = tier == "compressed"
    comp = Compactor(detector="gfsp", backend=backend)
    core_sweep.reset_trace_stats()      # also resets DECODE_STATS
    t0 = time.perf_counter()
    comp.run(store, stream=stream)
    detect_cold_ms = (time.perf_counter() - t0) * 1e3
    traces_cold = core_sweep.trace_count()
    decode_peak = int(DECODE_STATS["peak_resident_bytes"])
    t0 = time.perf_counter()
    comp.run(store, stream=stream)
    detect_warm_ms = (time.perf_counter() - t0) * 1e3
    traces_warm = core_sweep.trace_count() - traces_cold
    snap = comp.snapshot
    detect_digest = snap.digest()

    eng = QueryEngine(snap.fgraph)
    queries = _build_queries(snap.fgraph, store)
    res = eng.query_batch(queries, strategy="factorized", backend="host")
    t0 = time.perf_counter()
    res = eng.query_batch(queries, strategy="factorized", backend="host")
    query_warm_ms = (time.perf_counter() - t0) * 1e3

    out = {
        "shape": shape, "tier": tier, "backend": backend, "seed": seed,
        "n_triples": int(n), "n_terms": len(store.dict),
        "gen_ms": round(gen_ms, 1), "compress_ms": round(compress_ms, 1),
        "substrate_bytes": int(sub_bytes),
        "substrate_bytes_plain": int(plain_bytes),
        "bytes_per_triple": round(sub_bytes / max(n, 1), 2),
        "detect_cold_ms": round(detect_cold_ms, 1),
        "detect_warm_ms": round(detect_warm_ms, 1),
        "trace_count_cold": int(traces_cold),
        "trace_count_warm": int(traces_warm),
        "decode_peak_resident_bytes": decode_peak,
        "compacted_triples": int(snap.n_triples),
        "n_classes_planned": len(snap.fgraph.tables),
        "detect_digest": detect_digest,
        "n_queries": len(queries),
        "query_warm_ms": round(query_warm_ms, 2),
        "query_rows": int(sum(b.n_rows for b in res)),
        "query_digest": _digest(res),
    }
    if twin:
        out["twin"] = _twin_soak(snap, shape, twin, seed)
    out["rss_peak_kb"] = _rss_kb()
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--shape", required=True)
    ap.add_argument("--n", type=int, required=True)
    ap.add_argument("--tier", choices=("plain", "compressed"),
                    default="plain")
    ap.add_argument("--backend", default="host")
    ap.add_argument("--seed", type=int, default=7)
    ap.add_argument("--twin", type=int, default=0,
                    help="insert batches for the no-recompaction-twin "
                         "comparison (0 = skip)")
    args = ap.parse_args()
    cell = run_cell(args.shape, args.n, args.tier, args.backend,
                    args.seed, args.twin)
    sys.stdout.flush()
    print(json.dumps(cell))


if __name__ == "__main__":
    main()

"""Distributed-FSP roofline: lower the G.FSP device sweep for a
paper-scale workload on the production mesh and report the three roofline
terms (the paper's own workload as a dry-run cell -- §6 future work made
concrete).

Scale: LinkedSensorData D1D2D3 has 19.2M observations x 4 properties.
We lower the sweep at that full scale (ShapeDtypeStruct -- no data
materialization) on the 16x16 mesh.

NOTE: must run in its own process with 512 host devices
(``python -m benchmarks.bench_fsp_scale``); the aggregate ``run.py``
driver invokes it as a subprocess so the 1-device benches are unaffected.
"""
from __future__ import annotations

import json
import os
import sys


def lower_and_report() -> dict:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.core.distributed import sweep_drop_one
    from repro.launch import roofline as rl
    from repro.launch.mesh import make_production_mesh

    n_obs = 19_233_458            # paper Table 1a, D1D2D3 observations
    n_obs -= n_obs % 256          # row-shard evenly over the mesh
    k = 4                         # Observation property set size (A4)
    mesh = make_production_mesh()
    rows_sh = NamedSharding(mesh, P(("data",), None))
    rep = NamedSharding(mesh, P())
    objmat = jax.ShapeDtypeStruct((n_obs, k), jnp.int32)
    valid = jax.ShapeDtypeStruct((n_obs,), jnp.bool_)
    am = jax.ShapeDtypeStruct((), jnp.int32)
    out = []
    fn = jax.jit(lambda m, v, a: sweep_drop_one(m, v, a, k),
                 in_shardings=(rows_sh, NamedSharding(mesh, P("data")), rep),
                 out_shardings=(rep, rep))
    with mesh:
        compiled = fn.lower(objmat, valid, am).compile()
    roof = rl.analyze(compiled, n_chips=256,
                      model_flops=float(n_obs * k * 64))  # hash+sort work
    out.append({"bench": "fsp_sweep_sort_D1D2D3_256chips",
                "n_rows": n_obs, "k": k,
                "compute_s": roof.compute_s, "memory_s": roof.memory_s,
                "collective_s": roof.collective_s,
                "bottleneck": roof.bottleneck,
                "peak_GB": roof.memory_analysis["peak_bytes"] / 2**30,
                "collectives": roof.collectives["ops"]})

    # beyond-paper variant: hash-bucket exchange (one all_to_all) instead
    # of the distributed sort -- see core.distributed.ami_bucketed
    from repro.core.distributed import ami_bucketed

    def sweep_bucketed(m, v):
        amis = [ami_bucketed(jnp.delete(m, j, axis=1), v, mesh,
                             dp_axes=("data",)) for j in range(k)]
        return jnp.stack(amis)

    fn2 = jax.jit(sweep_bucketed,
                  in_shardings=(rows_sh, NamedSharding(mesh, P("data"))),
                  out_shardings=rep)
    with mesh:
        compiled2 = fn2.lower(objmat, valid).compile()
    roof2 = rl.analyze(compiled2, n_chips=256,
                       model_flops=float(n_obs * k * 64))
    out.append({"bench": "fsp_sweep_bucketed_D1D2D3_256chips",
                "n_rows": n_obs, "k": k,
                "compute_s": roof2.compute_s, "memory_s": roof2.memory_s,
                "collective_s": roof2.collective_s,
                "bottleneck": roof2.bottleneck,
                "peak_GB": roof2.memory_analysis["peak_bytes"] / 2**30,
                "collectives": roof2.collectives["ops"]})
    return out


def main() -> None:
    out = lower_and_report()
    d = os.path.join(os.path.dirname(__file__), "..", "experiments",
                     "bench")
    os.makedirs(d, exist_ok=True)
    with open(os.path.join(d, "fsp_scale.json"), "w") as f:
        json.dump(out, f, indent=1)
    print(json.dumps(out, indent=1))


if __name__ == "__main__":
    main()

"""Paper Table 5: NLE(G') and %Savings after factorizing each property
set A1-A10 over the graded datasets.  Validates the paper's claims:

  * A5 yields the best Observation savings (paper: ~49%);
  * A4 yields NEGATIVE savings ~-16.7% (factorization overhead, Fig. 7);
  * A8 yields the best Measurement savings (paper: up to 66.56%);
  * information is preserved (axiom expansion reproduces G exactly).
"""
from __future__ import annotations

import numpy as np

from repro.core import factorize, semantic_triples
from repro.data.synthetic import PROPERTY_SETS, property_set_ids

from .common import DATASETS, dataset, report


def run(fast: bool = False) -> list[dict]:
    rows = []
    names = list(DATASETS)[:1] if fast else list(DATASETS)
    best = {}
    for ds in names:
        for sid in PROPERTY_SETS:
            store = dataset(ds)
            cid, pids = property_set_ids(store, sid)
            res = factorize(store, cid, pids)
            # losslessness (Def. 4.10/4.11): axiom closure identical
            if sid in ("A5", "A8", "A4"):
                a = semantic_triples(store)
                b = semantic_triples(res.graph)
                assert a.shape == b.shape and (a == b).all(), sid
            rows.append({
                "dataset": ds, "SID": sid,
                "NLE_G": res.nle_before, "NLE_Gp": res.nle_after,
                "pct_savings": round(res.pct_savings_nle, 2),
            })
            best.setdefault(ds, {})[sid] = res.pct_savings_nle
    for ds in names:
        obs = {s: best[ds][s] for s in
               ("A1", "A2", "A3", "A4", "A5", "A6", "A7")}
        meas = {s: best[ds][s] for s in ("A8", "A9", "A10")}
        assert max(obs, key=obs.get) == "A5", (ds, obs)
        assert obs["A4"] < 0, (ds, obs)           # overhead case
        assert max(meas, key=meas.get) == "A8", (ds, meas)
    report("table5_savings", rows)
    return rows


if __name__ == "__main__":
    run()

"""Paper Table 5: NLE(G') and %Savings after factorizing each property
set A1-A10 over the graded datasets.  Validates the paper's claims:

  * A5 yields the best Observation savings (paper: ~49%);
  * A4 yields NEGATIVE savings ~-16.7% (factorization overhead, Fig. 7);
  * A8 yields the best Measurement savings (paper: up to 66.56%);
  * information is preserved (axiom expansion reproduces G exactly).

Caller-chosen property sets go through the unified pipeline as explicit
plans (``CompactionPlan.explicit`` + ``Compactor.execute``).  Also
micro-benchmarks surrogate minting (the bulk ``TermDict.ids`` allocation
used by Algorithm 3 vs the seed's per-group ``TermDict.id`` loop) and
the ingest hot path's molecule-table growth (the geometric append
buffer behind ``MoleculeTable.with_rows`` vs rebuilding by
concatenate-and-resort on every batch).
"""
from __future__ import annotations

import time

import numpy as np

from repro.api import CompactionPlan, Compactor
from repro.core.fgraph import MoleculeTable
from repro.core import semantic_triples
from repro.core.triples import TermDict
from repro.data.synthetic import PROPERTY_SETS, property_set_ids

from .common import DATASETS, dataset, report


def mint_bench(fast: bool = False) -> list[dict]:
    """Surrogate-id allocation: per-group id() loop vs one bulk ids()."""
    rows = []
    for n in ((10_000,) if fast else (10_000, 100_000, 400_000)):
        names = [f"repro:sg/bench/{i}" for i in range(n)]
        loop_dict = TermDict()
        t0 = time.perf_counter()
        for nm in names:
            loop_dict.id(nm)
        loop_ms = (time.perf_counter() - t0) * 1e3
        bulk_dict = TermDict()
        t0 = time.perf_counter()
        ids = bulk_dict.ids(names)
        bulk_ms = (time.perf_counter() - t0) * 1e3
        assert len(loop_dict) == len(bulk_dict)
        assert ids[0] == loop_dict.lookup(names[0])
        rows.append({"n_surrogates": n, "loop_ms": round(loop_ms, 2),
                     "bulk_ms": round(bulk_ms, 2),
                     "speedup": round(loop_ms / max(bulk_ms, 1e-9), 2)})
    report("surrogate_minting", rows)
    return rows


def with_rows_bench(fast: bool = False) -> list[dict]:
    """Molecule-table growth under online ingest: a chain of small
    ``with_rows`` appends (fresh ascending surrogates, the service's hot
    path) against the seed behavior of rebuilding the table from
    concatenated arrays -- O(rows added) amortized vs O(M) copy + sort
    per batch."""
    k = 3
    rows = []
    for n_batches in ((2_000,) if fast else (2_000, 8_000)):
        per = 8
        surr0 = np.arange(0, 64, dtype=np.int32)
        objs0 = np.arange(64 * k, dtype=np.int32).reshape(64, k)
        batches = [
            (np.arange(64 + b * per, 64 + (b + 1) * per, dtype=np.int32),
             np.arange((64 + b * per) * k, (64 + (b + 1) * per) * k,
                       dtype=np.int32).reshape(per, k))
            for b in range(n_batches)]

        amort = MoleculeTable(class_id=0, props=(1, 2, 3),
                              surrogates=surr0, objects=objs0,
                              next_ordinal=64)
        amort.sig           # exercise the O(n) sig ownership transfer too
        t0 = time.perf_counter()
        for s, o in batches:
            amort = amort.with_rows(s, o, int(s[-1]) + 1)
        amort_ms = (time.perf_counter() - t0) * 1e3

        naive = MoleculeTable(class_id=0, props=(1, 2, 3),
                              surrogates=surr0, objects=objs0,
                              next_ordinal=64)
        t0 = time.perf_counter()
        for s, o in batches:
            naive = MoleculeTable(
                class_id=naive.class_id, props=naive.props,
                surrogates=np.concatenate([naive.surrogates, s]),
                objects=np.concatenate([naive.objects, o]),
                next_ordinal=int(s[-1]) + 1)
        naive_ms = (time.perf_counter() - t0) * 1e3

        assert np.array_equal(amort.surrogates, naive.surrogates)
        assert np.array_equal(amort.objects, naive.objects)
        assert len(amort.sig) == amort.n_molecules
        rows.append({"n_batches": n_batches, "rows_per_batch": per,
                     "amortized_ms": round(amort_ms, 2),
                     "rebuild_ms": round(naive_ms, 2),
                     "speedup": round(naive_ms / max(amort_ms, 1e-9), 2)})
    report("with_rows_growth", rows)
    return rows


def run(fast: bool = False) -> list[dict]:
    rows = []
    names = list(DATASETS)[:1] if fast else list(DATASETS)
    best = {}
    comp = Compactor()
    for ds in names:
        for sid in PROPERTY_SETS:
            store = dataset(ds)
            cid, pids = property_set_ids(store, sid)
            rep = comp.execute(store,
                               CompactionPlan.explicit([(cid, pids)]))
            res = rep.factorizations[0]
            # losslessness (Def. 4.10/4.11): axiom closure identical
            if sid in ("A5", "A8", "A4"):
                a = semantic_triples(store)
                b = semantic_triples(res.graph)
                assert a.shape == b.shape and (a == b).all(), sid
            rows.append({
                "dataset": ds, "SID": sid,
                "NLE_G": res.nle_before, "NLE_Gp": res.nle_after,
                "pct_savings": round(res.pct_savings_nle, 2),
            })
            best.setdefault(ds, {})[sid] = res.pct_savings_nle
    for ds in names:
        obs = {s: best[ds][s] for s in
               ("A1", "A2", "A3", "A4", "A5", "A6", "A7")}
        meas = {s: best[ds][s] for s in ("A8", "A9", "A10")}
        assert max(obs, key=obs.get) == "A5", (ds, obs)
        assert obs["A4"] < 0, (ds, obs)           # overhead case
        assert max(meas, key=meas.get) == "A8", (ds, meas)
    report("table5_savings", rows)
    mint_bench(fast)
    with_rows_bench(fast)
    return rows


if __name__ == "__main__":
    run()

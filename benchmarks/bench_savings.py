"""Paper Table 5: NLE(G') and %Savings after factorizing each property
set A1-A10 over the graded datasets.  Validates the paper's claims:

  * A5 yields the best Observation savings (paper: ~49%);
  * A4 yields NEGATIVE savings ~-16.7% (factorization overhead, Fig. 7);
  * A8 yields the best Measurement savings (paper: up to 66.56%);
  * information is preserved (axiom expansion reproduces G exactly).

Caller-chosen property sets go through the unified pipeline as explicit
plans (``CompactionPlan.explicit`` + ``Compactor.execute``).  Also
micro-benchmarks surrogate minting: the bulk ``TermDict.ids`` allocation
used by Algorithm 3 vs the seed's per-group ``TermDict.id`` loop.
"""
from __future__ import annotations

import time

from repro.api import CompactionPlan, Compactor
from repro.core import semantic_triples
from repro.core.triples import TermDict
from repro.data.synthetic import PROPERTY_SETS, property_set_ids

from .common import DATASETS, dataset, report


def mint_bench(fast: bool = False) -> list[dict]:
    """Surrogate-id allocation: per-group id() loop vs one bulk ids()."""
    rows = []
    for n in ((10_000,) if fast else (10_000, 100_000, 400_000)):
        names = [f"repro:sg/bench/{i}" for i in range(n)]
        loop_dict = TermDict()
        t0 = time.perf_counter()
        for nm in names:
            loop_dict.id(nm)
        loop_ms = (time.perf_counter() - t0) * 1e3
        bulk_dict = TermDict()
        t0 = time.perf_counter()
        ids = bulk_dict.ids(names)
        bulk_ms = (time.perf_counter() - t0) * 1e3
        assert len(loop_dict) == len(bulk_dict)
        assert ids[0] == loop_dict.lookup(names[0])
        rows.append({"n_surrogates": n, "loop_ms": round(loop_ms, 2),
                     "bulk_ms": round(bulk_ms, 2),
                     "speedup": round(loop_ms / max(bulk_ms, 1e-9), 2)})
    report("surrogate_minting", rows)
    return rows


def run(fast: bool = False) -> list[dict]:
    rows = []
    names = list(DATASETS)[:1] if fast else list(DATASETS)
    best = {}
    comp = Compactor()
    for ds in names:
        for sid in PROPERTY_SETS:
            store = dataset(ds)
            cid, pids = property_set_ids(store, sid)
            rep = comp.execute(store,
                               CompactionPlan.explicit([(cid, pids)]))
            res = rep.factorizations[0]
            # losslessness (Def. 4.10/4.11): axiom closure identical
            if sid in ("A5", "A8", "A4"):
                a = semantic_triples(store)
                b = semantic_triples(res.graph)
                assert a.shape == b.shape and (a == b).all(), sid
            rows.append({
                "dataset": ds, "SID": sid,
                "NLE_G": res.nle_before, "NLE_Gp": res.nle_after,
                "pct_savings": round(res.pct_savings_nle, 2),
            })
            best.setdefault(ds, {})[sid] = res.pct_savings_nle
    for ds in names:
        obs = {s: best[ds][s] for s in
               ("A1", "A2", "A3", "A4", "A5", "A6", "A7")}
        meas = {s: best[ds][s] for s in ("A8", "A9", "A10")}
        assert max(obs, key=obs.get) == "A5", (ds, obs)
        assert obs["A4"] < 0, (ds, obs)           # overhead case
        assert max(meas, key=meas.get) == "A8", (ds, meas)
    report("table5_savings", rows)
    mint_bench(fast)
    return rows


if __name__ == "__main__":
    run()

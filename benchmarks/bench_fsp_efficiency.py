"""Paper Table 3: E.FSP vs G.FSP efficiency (PSIterations, #FSP, time).

Per observation type (the paper runs each phenomenon separately) and for
the Measurement class: the gSpan-backed exhaustive search vs the greedy
descent, plus our beyond-paper device paths (batched sweep, distributed
sweep).  Paper claims validated here:

  * E.FSP and G.FSP return the SAME frequent star patterns;
  * G.FSP is >= 3 orders of magnitude faster than E.FSP (gSpan
    enumeration included, as in the paper's timing).
"""
from __future__ import annotations

import numpy as np

from repro.api import Compactor
from repro.data.synthetic import MEASUREMENT, OBSERVATION, PHENOMENA

from .common import dataset, report, timeit

# detector x backend cells of the unified pipeline
E_FSP = Compactor(detector="efsp")
G_HOST = Compactor(detector="gfsp", backend="host")
G_DEVICE = Compactor(detector="gfsp", backend="device")
G_SHARDED = Compactor(detector="gfsp", backend="sharded")


def _subset(store, phenomenon: str):
    """Restrict the Observation class to one phenomenon (paper setup)."""
    pid = store.dict.lookup(f"phenom/{phenomenon}")
    if pid is None:
        return None
    prop = store.dict.lookup("ssn:observedProperty")
    ents = store.spo[(store.spo[:, 1] == prop) & (store.spo[:, 2] == pid), 0]
    return ents


def run(fast: bool = False) -> list[dict]:
    store = dataset("D1")
    rows = []
    cases = [("Measurement", MEASUREMENT)] + \
        [(ph, OBSERVATION) for ph in
         (PHENOMENA[:3] if fast else PHENOMENA)]
    for label, cname in cases:
        cid = store.dict.lookup(cname)
        if cname == OBSERVATION:
            # per-phenomenon subgraph, like the paper's per-type rows
            ents = _subset(store, label)
            sub = store.restrict_subjects(ents) if hasattr(
                store, "restrict_subjects") else store
            cid_l = cid
        else:
            sub, cid_l = store, cid

        t_e, r_e = timeit(lambda: E_FSP.detect(sub, cid_l), repeat=1)
        t_g, r_g = timeit(lambda: G_HOST.detect(sub, cid_l), repeat=1)
        t_gd, r_gd = timeit(lambda: G_DEVICE.detect(sub, cid_l), repeat=1)
        t_dist, r_dist = timeit(lambda: G_SHARDED.detect(sub, cid_l),
                                repeat=1)
        assert set(r_e.props) == set(r_g.props) == set(r_dist.props), \
            (label, r_e.props, r_g.props, r_dist.props)
        assert r_e.n_fsp == r_g.n_fsp == r_dist.n_fsp
        rows.append({
            "class": label,
            "PSIterations_E": r_e.iterations, "PSIterations_G":
                r_g.iterations,
            "num_FSP": r_g.n_fsp,
            "E_FSP_ms": round(r_e.exec_time_ms, 2),
            "G_FSP_ms": round(r_g.exec_time_ms, 2),
            "G_FSP_device_ms": round(t_gd, 2),
            "G_FSP_distributed_ms": round(t_dist, 2),
            "speedup_GvsE": round(r_e.exec_time_ms
                                  / max(r_g.exec_time_ms, 1e-9), 1),
        })
    report("table3_fsp_efficiency", rows)
    if not fast:
        scaling(rows)
    return rows


def scaling(rows: list[dict]) -> list[dict]:
    """G.FSP-vs-E.FSP speedup vs graph size (Measurement class).

    The paper's >=3-orders-of-magnitude gap is measured at 1.9M triples;
    this CPU container sweeps the feasible sizes and reports the growth
    trend (E.FSP's gSpan enumeration is super-linear in molecules, G.FSP
    is linear), which extrapolates to the paper's regime."""
    from repro.data.synthetic import SensorGraphSpec, generate

    out = []
    for n in (500, 1_000, 2_000, 4_000, 8_000):
        store = generate(SensorGraphSpec(n_observations=n, seed=9))
        cid = store.dict.lookup(MEASUREMENT)
        r_e = E_FSP.detect(store, cid)
        r_g = G_HOST.detect(store, cid)
        assert set(r_e.props) == set(r_g.props)
        out.append({"n_observations": n,
                    "E_FSP_ms": round(r_e.exec_time_ms, 1),
                    "G_FSP_ms": round(r_g.exec_time_ms, 1),
                    "speedup": round(r_e.exec_time_ms
                                     / max(r_g.exec_time_ms, 1e-9), 1)})
    # the gap must GROW with scale (claim: 3 orders at paper scale)
    assert out[-1]["speedup"] > out[0]["speedup"]
    report("table3_scaling", out)
    return out


if __name__ == "__main__":
    run()

"""Shared benchmark scaffolding: graded datasets, timing, reporting."""
from __future__ import annotations

import json
import os
import time
from typing import Callable

from repro.data.synthetic import SensorGraphSpec, generate

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                       "bench")

# graded datasets standing in for D1 / D1D2 / D1D2D3 (paper Table 1).
# Scale factor vs the paper: ~1:1000 (CPU container); the paper's ratios
# between datasets (x2.45, x1.92 observations) are preserved, and the
# value-repetition regime (AMI << AM) matches Fig. 8 so the savings
# asymptotics (A8 -> 66.6%, A5 -> 50%) are visible.
# timestamps scale with n (as in the real LinkedSensorData, where each
# observation carries a near-unique sampling time): keeps A4's object
# tuples near-unique (AMI ~ AM -> A4 max / overhead case) at every scale
DATASETS = {
    "D1": SensorGraphSpec(n_observations=4_000, n_timestamps=500, seed=1),
    "D1D2": SensorGraphSpec(n_observations=9_800, n_timestamps=1_225,
                            seed=2),
    "D1D2D3": SensorGraphSpec(n_observations=18_800, n_timestamps=2_350,
                              seed=3),
}

_CACHE: dict[str, object] = {}


def dataset(name: str):
    if name not in _CACHE:
        _CACHE[name] = generate(DATASETS[name])
    return _CACHE[name]


def timeit(fn: Callable, *, repeat: int = 3) -> tuple[float, object]:
    """(best_ms, last_result)."""
    best = float("inf")
    out = None
    for _ in range(repeat):
        t0 = time.perf_counter()
        out = fn()
        best = min(best, (time.perf_counter() - t0) * 1e3)
    return best, out


def report(name: str, rows: list[dict]) -> None:
    os.makedirs(OUT_DIR, exist_ok=True)
    path = os.path.join(OUT_DIR, f"{name}.json")
    with open(path, "w") as f:
        json.dump(rows, f, indent=1, default=str)
    if rows:
        cols = list(rows[0].keys())
        print(f"\n== {name} ==")
        print(",".join(cols))
        for r in rows:
            print(",".join(str(r.get(c, "")) for c in cols))

"""Benchmark driver: one module per paper table/figure + kernel micro +
the distributed-FSP roofline cell + the detector x backend perf snapshot
+ the star-query latency matrix (raw vs factorized x host/device)
+ the multi-star BGP matrix (cost-based planner vs fixed strategies)
+ the online-compaction drift matrix (soak via ``launch/serve.py``).

    python -m benchmarks.run [--fast]        # full paper suite
    python -m benchmarks.run --snapshot      # BENCH_fsp.json only (CI smoke)
"""
from __future__ import annotations

import hashlib
import json
import os
import subprocess
import sys
import time

SNAPSHOT_PATH = os.path.join(os.path.dirname(__file__), "..",
                             "BENCH_fsp.json")

# detector x backend cells of the unified pipeline; efsp is now
# backend-parametric (level-batched through the sweep engine); gspan is
# the honest enumeration baseline and stays host-only
SNAPSHOT_CELLS = [("gfsp", "host"), ("gfsp", "device"), ("gfsp", "sharded"),
                  ("efsp", "host"), ("efsp", "device"), ("efsp", "sharded"),
                  ("gspan", "host")]


# (scale x shape) grid cells: every cell runs on BOTH substrate tiers
# in its own subprocess (per-process ru_maxrss); the sensor shape rides
# the device backend so the grid carries a real jit path (zero warm
# retraces) at every scale.  The 1M tail drops the two shapes whose
# information content doesn't change with scale (hierarchy depth and
# the adversarial no-op are fully exercised at 100k).
SCALE_GRID = [
    (10_000, ("sensor", "skewed", "hierarchy", "reified", "adversarial")),
    (100_000, ("sensor", "skewed", "hierarchy", "reified", "adversarial")),
    (1_000_000, ("sensor", "skewed", "reified")),
]
SCALE_SMOKE = [(10_000, ("sensor", "skewed"))]

# (devices x graph size) shard matrix: every cell partitions the sensor
# graph into `devices` shards, detects shard-local (fork-parallel on
# multi-device cells) against an in-process replicated baseline, and
# fans the star workload out through the ShardedQueryEngine.  The
# subprocess gets a forced N-device jax host platform so the cross-
# shard AMI collective runs over a real mesh.
SHARD_GRID = [(d, n) for n in (100_000, 1_000_000) for d in (1, 2, 4, 8)]
SHARD_SMOKE = [(2, 100_000)]


def _run_scale_cell(shape: str, n: int, tier: str, *,
                    twin: int = 0, timeout: int = 900) -> dict:
    backend = "device" if shape == "sensor" else "host"
    cmd = [sys.executable, "-m", "benchmarks.scale_cell",
           "--shape", shape, "--n", str(n), "--tier", tier,
           "--backend", backend]
    if twin:
        cmd += ["--twin", str(twin)]
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(os.path.dirname(__file__), "..", "src"),
         env.get("PYTHONPATH", "")]).rstrip(os.pathsep)
    r = subprocess.run(cmd, capture_output=True, text=True,
                       timeout=timeout, env=env)
    if r.returncode != 0:
        raise RuntimeError(
            f"scale cell {shape}@{n}/{tier} failed:\n{r.stderr[-2000:]}")
    return json.loads(r.stdout.strip().splitlines()[-1])


def _run_shard_cell(devices: int, n: int, *, timeout: int = 1200) -> dict:
    cmd = [sys.executable, "-m", "benchmarks.shard_cell",
           "--devices", str(devices), "--n", str(n)]
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(os.path.dirname(__file__), "..", "src"),
         env.get("PYTHONPATH", "")]).rstrip(os.pathsep)
    env["XLA_FLAGS"] = \
        f"--xla_force_host_platform_device_count={devices}"
    r = subprocess.run(cmd, capture_output=True, text=True,
                       timeout=timeout, env=env)
    if r.returncode != 0:
        raise RuntimeError(
            f"shard cell {devices}dev@{n} failed:\n{r.stderr[-2000:]}")
    return json.loads(r.stdout.strip().splitlines()[-1])


def shard_matrix(grid=None) -> dict:
    """The (devices x graph size) shard matrix: detect + query wall-
    clock, per-shard resident bytes, and cross-shard traffic per cell,
    each cell in its own subprocess with a forced `devices`-device host
    platform.  Digest parity (sharded == replicated, per cell AND
    across device counts at the same scale) is asserted here at bench
    time; the committed numbers are re-gated by
    ``benchmarks.check_snapshot``."""
    cells = []
    digests: dict[int, str] = {}
    for devices, n in (grid or SHARD_GRID):
        c = _run_shard_cell(devices, n)
        assert c["detect_parity"], (devices, n, "sharded digest != "
                                    "replicated digest")
        assert c["query_parity"], (devices, n, "sharded query digest != "
                                   "replicated query digest")
        ref = digests.setdefault(n, c["detect_digest"])
        assert c["detect_digest"] == ref, \
            (devices, n, "digest moved across device counts")
        cells.append(c)
        frac = c["max_shard_resident_bytes"] / max(
            c["repl_resident_bytes"], 1)
        print(f"shard d={devices} n={n:>9,} "
              f"detect {c['detect_ms']:8.1f} ms "
              f"(crit {c['detect_critical_path_ms']:7.1f} ms)  "
              f"query warm {c['query_warm_ms']:7.1f} ms  "
              f"shard bytes {frac:.0%} of repl  "
              f"xfer {c['traffic']['detect_bytes'] + c['traffic']['query_bytes']:>9,} B  "
              f"parity ok")
    return {"cells": cells}


def shard_smoke() -> None:
    """CI smoke: the smallest multi-device shard cell, live, with the
    shard gates asserted in-process (digest parity both ways, zero warm
    retraces, a real collective over the forced 2-device mesh)."""
    res = shard_matrix(grid=SHARD_SMOKE)
    for c in res["cells"]:
        assert c["trace_count_warm"] == 0, c
        assert c["devices"] == 1 or c["traffic"]["collective_calls"] > 0, \
            "multi-device cell never ran the cross-shard collective"
        assert c["max_shard_resident_bytes"] < c["repl_resident_bytes"], \
            "a shard holds no fewer bytes than the replicated graph"
    print(f"shard-smoke OK ({len(res['cells'])} cells)")


def scale_matrix(grid=None) -> dict:
    """The (scale x shape) substrate grid: each cell = one workload
    shape at one scale, measured on the plain AND compressed tiers in
    separate subprocesses.  The plain cell also runs the per-cell
    no-recompaction-twin soak (edge advantage of online re-detection).
    Cross-tier digest parity is asserted here at bench time; the
    committed numbers are re-gated by ``benchmarks.check_snapshot``."""
    cells = []
    for n, shapes in (grid or SCALE_GRID):
        for shape in shapes:
            plain = _run_scale_cell(shape, n, "plain", twin=3)
            comp = _run_scale_cell(shape, n, "compressed")
            assert comp["detect_digest"] == plain["detect_digest"], \
                (shape, n, "detect digest diverged across tiers")
            assert comp["query_digest"] == plain["query_digest"], \
                (shape, n, "query digest diverged across tiers")
            ratio = comp["substrate_bytes"] / max(plain["substrate_bytes"],
                                                  1)
            for c in (plain, comp):
                c["compression_ratio"] = round(ratio, 4)
                cells.append(c)
            print(f"scale {shape:12s} n={n:>9,} "
                  f"B/triple {plain['bytes_per_triple']:6.1f} -> "
                  f"{comp['bytes_per_triple']:5.1f} ({ratio:.1%})  "
                  f"detect warm {plain['detect_warm_ms']:8.1f} / "
                  f"{comp['detect_warm_ms']:8.1f} ms  "
                  f"rss {plain['rss_peak_kb'] // 1024:4d} / "
                  f"{comp['rss_peak_kb'] // 1024:4d} MB  "
                  f"twin+{plain.get('twin', {}).get('edge_advantage', 0)}")
    return {"cells": cells}


def snapshot(fast: bool = True, scale: str | None = None,
             shard: str | None = None) -> dict:
    """FSP perf snapshot on the synthetic sensor graph.

    Each detector x backend cell runs TWICE: the cold pass pays jit
    tracing for the shape-bucketed sweep (one trace per power-of-two
    bucket -- recorded as ``trace_count_cold``), the warm pass must be
    pure cache hits (``trace_count_warm`` is asserted 0 for the jax
    backends by ``benchmarks.check_snapshot``).  Trace/exec counters
    reset between cells, so every count is per-cell (the jit cache
    itself is NOT dropped: later cells legitimately reuse earlier
    buckets); ``lowerings_per_descent`` must be exactly 1 on the
    batched paths.  Written to BENCH_fsp.json so the bench trajectory is
    tracked in CI."""
    from repro.api import Compactor
    from repro.core import sweep as core_sweep
    from repro.data.synthetic import SensorGraphSpec, generate

    n_obs = 800 if fast else 4_000
    store = generate(SensorGraphSpec(n_observations=n_obs, seed=42))
    cells = []
    reference = None
    bucket_shapes: dict[tuple, int] = {}

    def _lpd(lowerings: int, descents: int) -> float:
        return round(lowerings / descents, 4) if descents else 0.0

    for det, be in SNAPSHOT_CELLS:
        comp = Compactor(detector=det, backend=be)
        core_sweep.reset_trace_stats()     # per-cell counters, shared cache
        t0 = time.perf_counter()
        rep = comp.run(store)
        cold_ms = (time.perf_counter() - t0) * 1e3
        cold_detect = sum(d.exec_time_ms for d in rep.detections.values())
        traces_cold = core_sweep.trace_count()
        exec_cold = dict(core_sweep.EXEC_STATS)
        t0 = time.perf_counter()
        rep_warm = comp.run(store)
        warm_ms = (time.perf_counter() - t0) * 1e3
        warm_detect = sum(d.exec_time_ms
                          for d in rep_warm.detections.values())
        traces_warm = core_sweep.trace_count() - traces_cold
        warm_lowerings = core_sweep.EXEC_STATS["lowerings"] \
            - exec_cold["lowerings"]
        warm_descents = core_sweep.EXEC_STATS["descents"] \
            - exec_cold["descents"]
        for k, v in core_sweep.TRACE_COUNTS.items():
            bucket_shapes[k] = bucket_shapes.get(k, 0) + v
        dets = rep.detections
        cell = {
            "detector": det, "backend": be,
            "exec_time_ms": round(cold_ms, 2),
            "exec_time_ms_warm": round(warm_ms, 2),
            "detect_time_ms": round(cold_detect, 2),
            "detect_time_ms_warm": round(warm_detect, 2),
            "trace_count_cold": traces_cold,
            "trace_count_warm": traces_warm,
            "lowerings_per_descent": _lpd(exec_cold["lowerings"],
                                          exec_cold["descents"]),
            "lowerings_per_descent_warm": _lpd(warm_lowerings,
                                               warm_descents),
            "evaluations": int(sum(d.evaluations for d in dets.values())),
            "n_classes": len(rep.plan),
            "edges": {store.dict.term(c): d.edges for c, d in dets.items()},
            "pct_savings_triples": round(rep.pct_savings_triples, 2),
        }
        cells.append(cell)
        # every cell (and both passes) must compact to the identical graph
        if reference is None:
            reference = (cell["edges"], rep.n_triples_after)
        assert (cell["edges"], rep.n_triples_after) == reference, \
            (det, be, cell["edges"], reference)
        assert rep_warm.n_triples_after == rep.n_triples_after, (det, be)
    out = {
        "graph": {"n_observations": n_obs, "n_triples": store.n_triples,
                  "n_nodes": store.n_nodes, "seed": 42},
        "bucket_shapes": {
            "/".join(str(x) for x in k): v
            for k, v in sorted(bucket_shapes.items())},
        "cells": cells,
        "query": query_matrix(fast=fast),
        "bgp": bgp_matrix(fast=fast),
        "drift": drift_matrix(fast=fast),
        "recovery": recovery_matrix(fast=fast),
    }
    # the scale and shard grids are minutes of subprocesses: refresh
    # only when asked ("full"), otherwise carry the committed sections
    # forward so `--snapshot` (CI bench-smoke) keeps gating them
    if scale == "full":
        out["scale"] = scale_matrix()
    if shard == "full":
        out["shard_matrix"] = shard_matrix()
    if scale != "full" or shard != "full":
        try:
            with open(SNAPSHOT_PATH) as f:
                prev = json.load(f)
        except (OSError, ValueError):
            prev = {}
        for key, fresh in (("scale", scale), ("shard_matrix", shard)):
            if fresh != "full" and key in prev:
                out[key] = prev[key]
    with open(SNAPSHOT_PATH, "w") as f:
        json.dump(out, f, indent=1)
        f.write("\n")
    print(f"\n== BENCH_fsp snapshot ({os.path.abspath(SNAPSHOT_PATH)}) ==")
    for c in cells:
        print(f"{c['detector']:6s} x {c['backend']:8s} "
              f"cold {c['exec_time_ms']:9.1f} ms  "
              f"warm {c['exec_time_ms_warm']:8.1f} ms  "
              f"traces={c['trace_count_cold']}/{c['trace_count_warm']}  "
              f"low/desc={c['lowerings_per_descent_warm']:.1f}  "
              f"evals={c['evaluations']:<6d} "
              f"savings={c['pct_savings_triples']:.2f}%")
    return out


def recovery_matrix(fast: bool = True) -> dict:
    """Crash-point recovery sweep: durability as a gated number.

    A durable service (WAL + sync checkpoints every 3 applies) ingests
    a deterministic drift-heavy workload -- typed complete entities
    with novel object tuples, so re-detection genuinely runs -- while a
    seeded raise-mode :class:`~repro.dist.fault.FaultPlan` crashes it
    at ONE injection site.  The driver then :func:`~repro.online.recover`\\ s
    from disk and resubmits the interrupted batch (idempotent: RDF
    set semantics) and the run continues.  Every site x occurrence
    cell must (a) actually crash and (b) finish digest-identical to an
    uninterrupted plain-service reference over the same term-level
    batches -- zero lost or duplicated writes.  Per-cell recovery
    costs (checkpoint bytes, WAL replay ms, batches/mints replayed)
    are recorded; ``benchmarks.check_snapshot`` gates all of it."""
    import shutil
    import tempfile

    import numpy as np

    from repro.data.synthetic import SensorGraphSpec, generate
    from repro.dist.fault import SITES, FaultPlan, InjectedFault
    from repro.online import OnlineCompactionService, recover

    def build_store():
        return generate(SensorGraphSpec(n_observations=60, seed=5))

    def batches(store, n):
        """Deterministic term-level batches: complete typed entities
        with pairwise-novel object tuples (support-1 surrogates feed
        the drift tracker), every third batch deleting an earlier
        insert."""
        term = store.dict.term
        cid = int(store.classes()[0])
        props = np.asarray(store.class_properties(cid))
        cterm, tterm = term(cid), term(store.TYPE)
        pterms = [term(int(p)) for p in props]
        out = []
        for i in range(n):
            ins = []
            for j in range(3):
                s = f"e:n/b{i}/{j}"
                ins.append((s, tterm, cterm))
                ins += [(s, p, f"o:novel/b{i}/{j}/{k}")
                        for k, p in enumerate(pterms)]
            dels = [f"e:n/b{i - 2}/0"] if i % 3 == 2 else None
            out.append((ins, dels))
        return out

    kw = dict(detector="gfsp", backend="host", raw_residue_threshold=4,
              support_drift_threshold=3, retry_sleep=lambda _: None)
    n_batches = 10 if fast else 20
    seq = batches(build_store(), n_batches)

    ref = OnlineCompactionService(build_store(), **kw)
    for ins, dels in seq:
        ref.submit(inserts=ins, delete_entities=dels)
        ref.drain()
    ref_digest = ref.snapshot.digest()

    cells = []
    for site in SITES:
        for occ in (0, 1):
            root = tempfile.mkdtemp(prefix="fsp_recovery_")
            svc = OnlineCompactionService.durable(
                root, build_store(),
                fault_plan=FaultPlan(site, occurrence=occ),
                checkpoint_every=3, checkpoint_async=False, **kw)
            crashed, recoveries = False, 0
            for ins, dels in seq:
                for _ in range(2):
                    try:
                        svc.submit(inserts=ins, delete_entities=dels)
                        svc.drain()
                        break
                    except InjectedFault:
                        crashed = True
                        recoveries += 1
                        svc = recover(root, **kw)
                else:
                    raise AssertionError(f"{site} kept crashing")
            svc.close()
            rec = svc.last_recovery
            cells.append({
                "site": site, "occurrence": occ,
                "crashed": crashed,
                "parity": svc.snapshot.digest() == ref_digest,
                "drained": svc.queue.depth == 0,
                "n_recoveries": recoveries,
                "checkpoint_bytes": rec.checkpoint_bytes if rec else 0,
                "replay_ms": round(rec.replay_ms, 3) if rec else 0.0,
                "batches_replayed": rec.batches_pending if rec else 0,
                "mints_replayed": rec.mints_replayed if rec else 0,
            })
            shutil.rmtree(root, ignore_errors=True)
            c = cells[-1]
            print(f"recovery {site:18s} occ={occ} "
                  f"crashed={c['crashed']} parity={c['parity']} "
                  f"ckpt={c['checkpoint_bytes']}B "
                  f"replay={c['replay_ms']:.1f}ms "
                  f"batches={c['batches_replayed']}")
    return {"n_batches": n_batches, "ref_digest": ref_digest,
            "sites": list(SITES), "cells": cells}


def drift_matrix(fast: bool = True) -> dict:
    """Online-compaction soak: the per-batch drift matrix (recompaction
    latency, queue depth, dirty-class count, edge counts vs the
    no-recompaction twin) plus the service's metrics-channel summaries.
    Recorded with ``assert_gates=False`` so a gate regression shows up
    as a ``check_snapshot`` FAIL over the committed numbers rather than
    an opaque bench crash."""
    from repro.launch.serve import serve_online

    return serve_online(20 if fast else 40, assert_gates=False)


def query_matrix(fast: bool = True) -> dict:
    """Star-query latency matrix: raw vs factorized x host/device.

    The paper's claim is that frequent star patterns hurt *query
    processing*, not only size -- this makes it a gated number.  A
    frequent-pattern-heavy sensor graph (AM >> AMI) is compacted once;
    the workload is every molecule of each class looked up as a
    class-constrained all-ground star query (the shape the compaction
    targets), plus a variable-arm workload recorded for transparency
    (selective lookups favor G', whole-class scans favor the raw
    slices).  Every cell must produce identical binding sets (digest);
    ``factorized x host`` must be no slower than ``raw x host`` on the
    frequent-pattern-heavy class, and the batched device path must not
    retrace warm -- all gated in ``benchmarks.check_snapshot``.
    """
    from repro.api import Compactor
    from repro.core import sweep as core_sweep
    from repro.data.synthetic import SensorGraphSpec, generate
    from repro.query import QueryEngine, StarQuery

    n_obs = 4_000 if fast else 20_000
    store = generate(SensorGraphSpec(n_observations=n_obs, seed=42))
    comp = Compactor(detector="gfsp", backend="host")
    comp.run(store)
    fg = comp.fgraph
    eng = QueryEngine(fg)
    eng.raw_store         # build the expanded baseline outside the timers

    # frequent-pattern-heavy class = largest AM / AMI ratio
    def _ratio(cid):
        t = fg.tables[cid]
        return fg.support(cid).sum() / max(t.n_molecules, 1)
    heavy = max(fg.tables, key=_ratio)

    lookups: list[StarQuery] = []
    for cid, t in sorted(fg.tables.items()):
        for row in t.objects:
            lookups.append(StarQuery(
                arms=tuple((p, int(o)) for p, o in zip(t.props, row)),
                class_id=cid))
    heavy_lookups = [q for q in lookups if q.class_id == heavy]
    var_queries = [
        StarQuery(arms=((t.props[0], int(row[0])), (t.props[-1], None)),
                  class_id=cid)
        for cid, t in sorted(fg.tables.items()) for row in t.objects[:32]]

    def _digest(bindings) -> str:
        h = hashlib.sha1()
        for b in bindings:
            h.update(b.canonical().tobytes())
        return h.hexdigest()[:16]

    def _cell(workload, strategy, backend):
        core_sweep.reset_trace_stats()
        t0 = time.perf_counter()
        res = eng.query_batch(workload, strategy=strategy, backend=backend)
        cold = (time.perf_counter() - t0) * 1e3
        traces_cold = core_sweep.trace_count()
        t0 = time.perf_counter()
        res = eng.query_batch(workload, strategy=strategy, backend=backend)
        warm = (time.perf_counter() - t0) * 1e3
        return res, {
            "strategy": strategy, "backend": backend,
            "exec_time_ms": round(cold, 3),
            "exec_time_ms_warm": round(warm, 3),
            "trace_count_cold": traces_cold,
            "trace_count_warm": core_sweep.trace_count() - traces_cold,
            "n_queries": len(workload),
            "n_rows": int(sum(b.n_rows for b in res)),
            "digest": _digest(res),
        }

    out: dict = {
        "graph": {"n_observations": n_obs, "n_triples": store.n_triples,
                  "seed": 42},
        "heavy_class": store.dict.term(heavy),
        "workloads": {},
    }
    for wname, workload in (("lookup", lookups),
                            ("lookup_heavy", heavy_lookups),
                            ("var_arm", var_queries)):
        cells = []
        for strategy, backend in (("raw", "host"), ("factorized", "host"),
                                  ("factorized", "device")):
            _, cell = _cell(workload, strategy, backend)
            cells.append(cell)
        out["workloads"][wname] = cells
        base = cells[0]["exec_time_ms_warm"]
        for c in cells:
            tag = f"{c['strategy']}x{c['backend']}"
            print(f"query {wname:13s} {tag:18s} "
                  f"cold {c['exec_time_ms']:8.1f} ms  "
                  f"warm {c['exec_time_ms_warm']:8.1f} ms  "
                  f"({base / max(c['exec_time_ms_warm'], 1e-9):4.2f}x raw) "
                  f"rows={c['n_rows']} digest={c['digest']}")
    return out


def bgp_matrix(fast: bool = True) -> dict:
    """Multi-star BGP matrix: planner vs fixed strategies x host/device.

    Six workloads over the sensor graph WITH ssn:Sensor metadata stars
    (so cross-star joins have a factorizable class on both sides):
    molecule ``lookup``s, ``var_arm`` scans, pushed-down value
    ``filter``s (plus a post-hoc cell over the identical queries),
    molecule-to-molecule ``2star`` joins, ``3star`` chains, and a
    ``mixed`` bag spanning all shapes.  Gated invariants
    (``benchmarks.check_snapshot``): every cell of a workload returns
    the identical binding-set digest; the batched device join path does
    not retrace warm; the factorized ``2star`` intermediate is bounded
    by molecule counts (AMI x AMI) strictly below raw's entity-level
    frontier; pushed-down filtering beats post-hoc; the cost-based
    planner's warm latency on ``mixed`` is no worse than EITHER fixed
    strategy -- the per-star choice must pay for itself -- and on
    ``filter``/``3star`` stays within 15% of the best fixed strategy
    (the mixed-slot re-pricing closing ROADMAP item 1').  The matrix
    also re-runs the cost-model calibration
    (``repro.query.bgp.calibrate``) and records the fitted constants
    next to the committed defaults so drift is visible per commit.
    """
    from repro.api import Compactor
    from repro.core import sweep as core_sweep
    from repro.data.synthetic import (MEASUREMENT, OBSERVATION, P_MODEL,
                                      P_PROCEDURE, P_RESULT, P_VALUE,
                                      SENSOR, SensorGraphSpec, generate)
    from repro.query import BGPQuery, Filter, QueryEngine, StarPattern

    n_obs = 4_000 if fast else 20_000
    store = generate(SensorGraphSpec(n_observations=n_obs, seed=42,
                                     include_sensor_metadata=True))
    comp = Compactor(detector="gfsp", backend="host")
    comp.run(store)
    fg = comp.fgraph
    eng = QueryEngine(fg)
    eng.raw_store         # build the expanded baseline outside the timers
    d = store.dict
    obs, meas, sen = (d.lookup(t) for t in (OBSERVATION, MEASUREMENT,
                                            SENSOR))
    p_proc, p_model, p_res, p_val = (
        d.lookup(t) for t in (P_PROCEDURE, P_MODEL, P_RESULT, P_VALUE))

    lookups = [
        BGPQuery(stars=(StarPattern(
            "?s", tuple((int(p), int(o)) for p, o in zip(t.props, row)),
            class_id=cid),))
        for cid, t in sorted(fg.tables.items()) for row in t.objects[:48]]
    var_arm = [
        BGPQuery(stars=(StarPattern(
            "?s", ((int(t.props[0]), int(row[0])),
                   (int(t.props[-1]), "?v")), class_id=cid),))
        for cid, t in sorted(fg.tables.items()) for row in t.objects[:16]]
    # raw's home turf: var arms over the residual (off-SP) property --
    # distinct var labels keep the queries (and their cache entries)
    # separate while probing the same shape
    residual = [BGPQuery(stars=(StarPattern(
        f"?o{i}", ((p_res, f"?m{i}"),), class_id=obs),))
        for i in range(3)]
    joins2 = [
        BGPQuery(stars=(
            StarPattern("?o", ((p_proc, "?s"),), class_id=obs),
            StarPattern("?s", ((p_model, d.lookup(f"model/{m}")),),
                        class_id=sen)))
        for m in range(3)]
    chains3 = [
        BGPQuery(stars=(
            StarPattern("?o", ((p_proc, "?s"), (p_res, "?m")),
                        class_id=obs),
            StarPattern("?s", ((p_model, d.lookup(f"model/{m}")),),
                        class_id=sen),
            StarPattern("?m", ((p_val, "?v"),), class_id=meas)))
        for m in range(3)]
    # pushed-down value filters riding the 3-star chain: the pushed form
    # prunes measurement molecules BEFORE the joins, post-hoc carries
    # the full join frontier to the end
    filtered = [
        BGPQuery(stars=q.stars,
                 filters=(Filter("?v", op, d.lookup(f"val/{k}")),))
        for q in chains3 for op in ("<", ">=") for k in (2, 6)]
    # every shape is represented; weights follow the serving mix the
    # README describes (lookup-dominated with a steady join/scan tail)
    mixed = (lookups[:24] + var_arm[:8] + residual + joins2 * 2
             + filtered[:2])

    def _digest(results) -> str:
        h = hashlib.sha1()
        for b in results:
            h.update(b.canonical().tobytes())
        return h.hexdigest()[:16]

    def _cell(workload, label, strategy, backend, posthoc=False):
        def run_once():
            out, mi = [], 0
            for q in workload:
                b, stq = eng.query_bgp(q, strategy=strategy,
                                       backend=backend,
                                       posthoc_filters=posthoc,
                                       return_stats=True)
                out.append(b)
                mi = max(mi, stq["max_intermediate"])
            return out, mi
        core_sweep.reset_trace_stats()
        t0 = time.perf_counter()
        res, mi = run_once()
        cold = (time.perf_counter() - t0) * 1e3
        traces_cold = core_sweep.trace_count()
        # best-of-3 warm: the planner gates run at 1.15x slack on ~10 ms
        # cells, which a single sample cannot resolve above host jitter
        warm = float("inf")
        for _ in range(3):
            t0 = time.perf_counter()
            res, mi = run_once()
            warm = min(warm, (time.perf_counter() - t0) * 1e3)
        return {
            "strategy": label, "backend": backend,
            "exec_time_ms": round(cold, 3),
            "exec_time_ms_warm": round(warm, 3),
            "trace_count_cold": traces_cold,
            "trace_count_warm": core_sweep.trace_count() - traces_cold,
            "n_queries": len(workload),
            "n_rows": int(sum(b.n_rows for b in res)),
            "max_intermediate": int(mi),
            "digest": _digest(res),
        }

    out: dict = {
        "graph": {"n_observations": n_obs, "n_triples": store.n_triples,
                  "seed": 42, "sensor_metadata": True},
        "workloads": {},
    }
    for wname, workload in (("lookup", lookups), ("var_arm", var_arm),
                            ("filter", filtered), ("2star", joins2),
                            ("3star", chains3), ("mixed", mixed)):
        cells = [
            _cell(workload, "planner", "auto", "host"),
            _cell(workload, "raw", "raw", "host"),
            _cell(workload, "factorized", "factorized", "host"),
            _cell(workload, "factorized", "factorized", "device"),
        ]
        if wname == "filter":       # identical queries, filters applied last
            cells.append(_cell(workload, "posthoc", "factorized", "host",
                               posthoc=True))
        out["workloads"][wname] = cells
        for c in cells:
            tag = f"{c['strategy']}x{c['backend']}"
            print(f"bgp {wname:8s} {tag:18s} "
                  f"cold {c['exec_time_ms']:8.1f} ms  "
                  f"warm {c['exec_time_ms_warm']:8.1f} ms  "
                  f"maxint={c['max_intermediate']:<7d} "
                  f"rows={c['n_rows']} digest={c['digest']}")
    from repro.query.bgp import calibration_report
    out["calibration"] = calibration_report(eng, {
        "lookup": lookups, "var_arm": var_arm, "filter": filtered,
        "2star": joins2, "3star": chains3, "residual": residual})
    print(f"bgp calibration n={out['calibration']['n_samples']} "
          f"rel_l1={out['calibration']['rel_l1_error']} "
          f"fitted={out['calibration']['fitted']}")
    return out


def scale_smoke() -> None:
    """CI smoke: the two smallest grid cells, live, with the scale
    gates asserted in-process (bytes-per-triple halved, digest parity
    across tiers, zero warm retraces, bounded resident decodes)."""
    res = scale_matrix(grid=SCALE_SMOKE)
    by_key = {(c["shape"], c["n_triples"], c["tier"]): c
              for c in res["cells"]}
    for (shape, n, tier), c in by_key.items():
        if tier != "compressed":
            continue
        p = by_key[(shape, n, "plain")]
        assert c["substrate_bytes"] <= 0.5 * p["substrate_bytes"], \
            (shape, "compressed substrate must be <= half of plain")
        assert c["detect_digest"] == p["detect_digest"]
        assert c["query_digest"] == p["query_digest"]
        assert c["trace_count_warm"] == 0 and p["trace_count_warm"] == 0
        assert c["decode_peak_resident_bytes"] <= \
            0.35 * p["substrate_bytes"], \
            (shape, "streamed detection held too much decoded")
    print(f"scale-smoke OK ({len(by_key)} cells)")


def main() -> None:
    argv = sys.argv[1:]
    fast = "--fast" in argv
    if "--scale-smoke" in argv:
        scale_smoke()
        return
    if "--shard-smoke" in argv:
        shard_smoke()
        return
    if "--snapshot" in argv:
        snapshot(fast=True,
                 scale="full" if "--scale" in argv else None,
                 shard="full" if "--shard" in argv else None)
        return
    from . import (bench_formula, bench_fsp_efficiency, bench_kernels,
                   bench_nodes_edges, bench_repeats, bench_savings)
    t0 = time.time()
    bench_fsp_efficiency.run(fast)      # Table 3
    bench_formula.run(fast)             # Table 4
    bench_savings.run(fast)             # Table 5 + surrogate minting
    bench_repeats.run(fast)             # Figure 8
    bench_nodes_edges.run(fast)         # Figure 9
    bench_kernels.run(fast)             # kernels
    snapshot(fast=fast)                 # detector x backend matrix
    if not fast:
        # separate process: needs 512 host devices before jax init
        r = subprocess.run([sys.executable, "-m",
                            "benchmarks.bench_fsp_scale"],
                           capture_output=True, text=True, timeout=1800)
        print(r.stdout[-2000:] if r.returncode == 0
              else f"fsp_scale FAILED:\n{r.stderr[-2000:]}")
    print(f"\nall benchmarks done in {time.time() - t0:.1f}s")


if __name__ == "__main__":
    main()

"""Benchmark driver: one module per paper table/figure + kernel micro +
the distributed-FSP roofline cell.  ``python -m benchmarks.run [--fast]``.
"""
from __future__ import annotations

import subprocess
import sys
import time


def main() -> None:
    fast = "--fast" in sys.argv
    from . import (bench_formula, bench_fsp_efficiency, bench_kernels,
                   bench_nodes_edges, bench_repeats, bench_savings)
    t0 = time.time()
    bench_fsp_efficiency.run(fast)      # Table 3
    bench_formula.run(fast)             # Table 4
    bench_savings.run(fast)             # Table 5
    bench_repeats.run(fast)             # Figure 8
    bench_nodes_edges.run(fast)         # Figure 9
    bench_kernels.run(fast)             # kernels
    if not fast:
        # separate process: needs 512 host devices before jax init
        r = subprocess.run([sys.executable, "-m",
                            "benchmarks.bench_fsp_scale"],
                           capture_output=True, text=True, timeout=1800)
        print(r.stdout[-2000:] if r.returncode == 0
              else f"fsp_scale FAILED:\n{r.stderr[-2000:]}")
    print(f"\nall benchmarks done in {time.time() - t0:.1f}s")


if __name__ == "__main__":
    main()

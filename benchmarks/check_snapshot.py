"""CI gate over BENCH_fsp.json (the ``--snapshot`` output).

Asserts the structural invariants the bench-smoke job exists to protect:

1. **Cross-backend parity** -- every detector x backend cell reports the
   same per-class #Edges and the same triple savings (all cells compact
   to the identical graph).  efsp <-> gfsp parity on the classes both
   detect is additionally checked class-by-class: the exhaustive and
   greedy detectors must agree exactly (paper Theorem 4.1 claim).
2. **Warm accelerator speed** -- once the shape-bucketed sweep is
   compiled, the device backend's detection time must stay within
   ``MAX_WARM_RATIO`` x the host loop on the 800-observation snapshot
   graph (the seed regression this guards against was ~95x), and the
   level-batched efsp cells must stay within ``MAX_EFSP_WARM_RATIO`` x
   the gfsp host loop (the gSpan-backed efsp was ~270x).
3. **Bounded retracing** -- warm passes of the jax backends must be pure
   jit-cache hits (``trace_count_warm == 0``).
4. **One lowering per descent** -- on the candidate-batched device and
   sharded paths every warm logical sweep (greedy descent step or efsp
   lattice level) must dispatch exactly one compiled lowering.
5. **Query correctness and payoff** -- every star-query cell of every
   workload returns the identical binding-set digest (raw == factorized
   == batched-device, the Def. 4.11 equivalence), the factorized host
   strategy is no slower than the raw baseline on the molecule-lookup
   workload of the frequent-pattern-heavy class (the paper's "queries
   get faster on G'" claim), and the batched device query path does not
   retrace warm.
6. **The BGP engine pays** -- every cell of every multi-star workload
   returns the identical binding-set digest (planner == fixed-raw ==
   fixed-factorized == batched-device, filters pushed or post-hoc); the
   batched join path does not retrace warm; the factorized 2-star join
   runs at molecule granularity (its max intermediate strictly below
   raw's entity-level frontier -- AMI x AMI, not AM x AM); pushed-down
   filters are no slower than post-hoc filtering of the identical
   queries; the cost-based planner's warm latency on the mixed
   workload is no worse than either fixed strategy; on the filter and
   3star workloads -- where the pre-``c_mix`` model sat ~25% behind
   raw (ROADMAP item 1') -- the planner stays within
   ``MAX_PLANNER_SLACK`` of the best fixed strategy; and the
   recorded cost-model calibration fitted a positive mixed-slot
   constant from identifying samples.
7. **Online compaction pays** -- the drift matrix from the
   ``launch/serve.py --online`` soak must show a drained write-ahead
   queue, zero warm retraces on forced re-detection, a service edge
   count never above the no-recompaction twin, per-pass realized-edge
   monotonicity (the planner's hill-climb guard), a final edge
   advantage strictly better than the initial one, and digest parity
   between the incremental final state and a from-scratch compaction of
   the net graph.
8. **The compressed substrate holds** -- every (scale x shape) grid
   cell's compressed tier stores at most half the plain tier's
   substrate bytes, answers detection and the star workload with
   byte-identical digests, never retraces warm, keeps streamed
   detection's resident decodes bounded by a fraction of the plain
   substrate (peak RSS ~ largest class bucket, not the graph), stays
   under per-scale whole-process RSS budgets, and the per-cell
   no-recompaction-twin soak never shows recompaction losing edges.
9. **Sharding pays and stays lossless** -- every (devices x graph size)
   cell of the shard matrix must show digest parity sharded ==
   replicated for detection AND the star workload (the Def. 4.10
   invariance under partitioning), an unchanged digest across device
   counts at the same scale, zero warm retraces on the fan-out device
   query path, a real cross-shard collective on every multi-device
   cell with a chunk-split class, 4-device detection's parallel
   critical path (max per-shard worker CPU time) at most
   ``MAX_SHARD_DETECT_RATIO`` x the 1-device detect on the 1M sensor
   cell -- plus the raw wall-clock comparison whenever the recording
   host had a core per shard -- and per-shard resident bytes at most
   ``MAX_SHARD_RESIDENT_FRAC`` of the replicated graph on every
   >=4-device cell.
10. **Crash durability holds** -- the recovery matrix (raise-mode
   crash-point sweep over every fault-injection site) must show every
   site x occurrence cell actually crashing, recovering from the WAL +
   checkpoint with a drained queue, and finishing digest-identical to
   the uninterrupted reference (zero lost or duplicated writes); every
   recovery records a positive checkpoint size and its replay cost.
   The drift soak's service must also carry the fault-telemetry
   channels (``fault.retries``, ``fault.dead_workers``,
   ``ingest.unknown_deletes``) so retry storms, dead workers, and
   silently-dropped deletes are visible per commit.

    python -m benchmarks.check_snapshot [path/to/BENCH_fsp.json]
"""
from __future__ import annotations

import json
import os
import sys

MAX_WARM_RATIO = 3.0
MAX_EFSP_WARM_RATIO = 50.0
# wall clocks on shared CI runners jitter; forgive sub-millisecond hosts
MIN_HOST_MS = 1.0
# planner-vs-best-fixed slack on the filter/3star chains: the planner
# and raw are within noise of each other there by design (the c_mix
# re-pricing flips the granularity-crossing star to raw), so the gate
# allows measurement jitter while still catching the ~25% regression
# shape it exists for
MAX_PLANNER_SLACK = 1.15

# cells whose sweeps run through the candidate-batched compiled engine.
# The == 1.0 lowerings-per-descent bound is EXACT for these cells on the
# pinned snapshot graph: efsp slices lattice levels to engine-sized
# chunks at the detector, gfsp drop-one stacks are k_bucket <= 256, and
# every sensor class executes at least one sweep (so descents > 0).
BATCHED_CELLS = (("gfsp", "device"), ("gfsp", "sharded"),
                 ("efsp", "device"), ("efsp", "sharded"))

DEFAULT_PATH = os.path.join(os.path.dirname(__file__), "..",
                            "BENCH_fsp.json")


def check(path: str = DEFAULT_PATH) -> list[str]:
    with open(path) as f:
        snap = json.load(f)
    cells = snap["cells"]
    errors: list[str] = []

    by_key = {(c["detector"], c["backend"]): c for c in cells}
    ref = cells[0]
    for c in cells[1:]:
        if c["edges"] != ref["edges"]:
            errors.append(
                f"edges parity broken: {c['detector']}x{c['backend']} "
                f"{c['edges']} != {ref['edges']}")
        if c["pct_savings_triples"] != ref["pct_savings_triples"]:
            errors.append(
                f"savings parity broken: {c['detector']}x{c['backend']} "
                f"{c['pct_savings_triples']} != "
                f"{ref['pct_savings_triples']}")

    host = by_key.get(("gfsp", "host"))
    device = by_key.get(("gfsp", "device"))
    if host and device:
        host_ms = max(host["detect_time_ms"], MIN_HOST_MS)
        warm_ms = device["detect_time_ms_warm"]
        if warm_ms > MAX_WARM_RATIO * host_ms:
            errors.append(
                f"warm device detect {warm_ms:.1f} ms exceeds "
                f"{MAX_WARM_RATIO}x host {host_ms:.1f} ms")

    # efsp <-> gfsp agreement on the shared classes, class by class
    efsp_host = by_key.get(("efsp", "host"))
    if host and efsp_host:
        shared = set(host["edges"]) & set(efsp_host["edges"])
        if not shared:
            errors.append("efsp and gfsp detected no common class")
        for cls in sorted(shared):
            if efsp_host["edges"][cls] != host["edges"][cls]:
                errors.append(
                    f"efsp/gfsp edge parity broken on {cls}: "
                    f"{efsp_host['edges'][cls]} != {host['edges'][cls]}")
        if efsp_host["pct_savings_triples"] != host["pct_savings_triples"]:
            errors.append(
                f"efsp/gfsp savings parity broken: "
                f"{efsp_host['pct_savings_triples']} != "
                f"{host['pct_savings_triples']}")
        host_ms = max(host["detect_time_ms"], MIN_HOST_MS)
        for be in ("host", "device", "sharded"):
            cell = by_key.get(("efsp", be))
            if not cell:
                continue
            warm_ms = cell["detect_time_ms_warm"]
            if warm_ms > MAX_EFSP_WARM_RATIO * host_ms:
                errors.append(
                    f"warm efsp x {be} detect {warm_ms:.1f} ms exceeds "
                    f"{MAX_EFSP_WARM_RATIO}x gfsp host {host_ms:.1f} ms")

    for key in BATCHED_CELLS:
        cell = by_key.get(key)
        if not cell:
            continue
        if cell.get("trace_count_warm", 0) != 0:
            errors.append(f"{key[0]}x{key[1]} retraced on the warm pass "
                          f"({cell['trace_count_warm']} traces)")
        lpd = cell.get("lowerings_per_descent_warm")
        if lpd != 1.0:
            errors.append(
                f"{key[0]}x{key[1]} warm lowerings_per_descent is {lpd!r}, "
                f"expected exactly 1.0 (candidate batching regressed)")

    errors.extend(check_query(snap.get("query")))
    errors.extend(check_bgp(snap.get("bgp")))
    errors.extend(check_drift(snap.get("drift")))
    errors.extend(check_recovery(snap.get("recovery")))
    errors.extend(check_scale(snap.get("scale")))
    errors.extend(check_shard(snap.get("shard_matrix")))
    return errors


# 4-device detection must reach <= this fraction of the 1-device detect
# on the 1M sensor cell.  The comparison runs on the parallel critical
# path (max per-shard worker CPU time): that is the quantity the
# partition balance controls, and the wall-clock the fork fan-out
# reaches with a core per shard.  The raw wall-clock is gated too
# whenever the recording host actually had >= 4 cores.
MAX_SHARD_DETECT_RATIO = 0.6
# on >= 4-device cells, no shard may hold more than this fraction of
# the replicated graph's resident bytes (substrate + molecule tables,
# shared dictionary excluded on both sides)
MAX_SHARD_RESIDENT_FRAC = 0.35
SHARD_GATE_SCALE = 1_000_000


def check_shard(shard: dict | None) -> list[str]:
    """Gate the (devices x graph size) shard matrix (item 9)."""
    errors: list[str] = []
    if not shard or not shard.get("cells"):
        errors.append("snapshot has no shard matrix "
                      "(rerun --snapshot --shard)")
        return errors
    cells = shard["cells"]
    by_key = {(c["devices"], c["n_triples"]): c for c in cells}
    scales = sorted({c["n_triples"] for c in cells})
    if max((c["devices"] for c in cells), default=0) < 4:
        errors.append("shard matrix has no >= 4-device cell")
    digests: dict[int, str] = {}
    for c in sorted(cells, key=lambda c: (c["n_triples"], c["devices"])):
        tag = f"shard[{c['devices']}dev@{c['n_triples']}]"
        if not c.get("detect_parity"):
            errors.append(f"{tag} sharded detect digest diverged from "
                          f"the replicated baseline")
        if not c.get("query_parity"):
            errors.append(f"{tag} fan-out binding sets diverged from "
                          f"the replicated engine")
        ref = digests.setdefault(c["n_triples"], c["detect_digest"])
        if c["detect_digest"] != ref:
            errors.append(f"{tag} digest moved across device counts "
                          f"({c['detect_digest']} != {ref})")
        if c.get("trace_count_warm", 0) != 0:
            errors.append(f"{tag} fan-out device query path retraced on "
                          f"the warm pass ({c['trace_count_warm']})")
        if c["devices"] > 1 and c.get("split_classes", 0) > 0 \
                and c["traffic"].get("collective_calls", 0) == 0:
            errors.append(f"{tag} has chunk-split classes but never ran "
                          f"the cross-shard AMI collective")
        if c["devices"] >= 4:
            frac = c["max_shard_resident_bytes"] / max(
                c["repl_resident_bytes"], 1)
            if frac > MAX_SHARD_RESIDENT_FRAC:
                errors.append(
                    f"{tag} a shard holds {frac:.0%} of the replicated "
                    f"resident bytes (over {MAX_SHARD_RESIDENT_FRAC:.0%}"
                    f": the partition no longer scales memory down)")
    for n in scales:
        one = by_key.get((1, n))
        four = by_key.get((4, n))
        if n != max(scales) or not one or not four:
            continue
        base = max(one["detect_critical_path_ms"], MIN_HOST_MS)
        crit = four["detect_critical_path_ms"]
        if crit > MAX_SHARD_DETECT_RATIO * base:
            errors.append(
                f"shard[4dev@{n}] parallel detect critical path "
                f"{crit:.0f} ms exceeds {MAX_SHARD_DETECT_RATIO}x the "
                f"1-device detect {base:.0f} ms")
        if four.get("cpu_count", 1) >= 4 \
                and four["detect_ms"] > \
                MAX_SHARD_DETECT_RATIO * max(one["detect_ms"],
                                             MIN_HOST_MS):
            errors.append(
                f"shard[4dev@{n}] detect wall-clock "
                f"{four['detect_ms']:.0f} ms exceeds "
                f"{MAX_SHARD_DETECT_RATIO}x the 1-device "
                f"{one['detect_ms']:.0f} ms on a "
                f"{four['cpu_count']}-core host")
    return errors


# per-scale whole-process RSS budgets (KiB).  Generous on purpose: the
# number includes the jax runtime and the generation phase (which
# necessarily materializes uncompressed arrays); the *tight* memory
# claims ride the deterministic substrate/decode byte columns below.
RSS_BUDGET_KB = {10_000: 1_500_000, 100_000: 1_500_000,
                 1_000_000: 3_000_000}
# compressed substrate must be at most half the plain tier's bytes
MAX_COMPRESSED_RATIO = 0.5
# streamed detection may hold at most this fraction of the plain
# substrate decoded at once (in practice it's the largest class bucket)
MAX_DECODE_RESIDENT_FRAC = 0.35


def check_scale(scale: dict | None) -> list[str]:
    """Gate the (scale x shape) substrate grid (item 8).

    Every cell pair (plain, compressed) must agree on detect and query
    digests; the compressed tier must hold at most
    ``MAX_COMPRESSED_RATIO`` of the plain substrate bytes; warm passes
    must not retrace; streamed detection must keep resident decodes
    bounded; whole-process peak RSS stays under per-scale budgets; and
    the per-cell twin soak must never leave recompaction behind the
    no-recompaction baseline."""
    errors: list[str] = []
    if not scale or not scale.get("cells"):
        errors.append("snapshot has no scale grid "
                      "(rerun --snapshot --scale)")
        return errors
    cells = scale["cells"]
    by_key = {(c["shape"], c["n_triples"], c["tier"]): c for c in cells}
    scales = sorted({c["n_triples"] for c in cells})
    shapes = sorted({c["shape"] for c in cells})
    if len(scales) < 3:
        errors.append(f"scale grid spans {len(scales)} scales, need >= 3")
    if len(shapes) < 3:
        errors.append(f"scale grid spans {len(shapes)} shapes, need >= 3")
    if max(scales, default=0) < 1_000_000:
        errors.append("scale grid has no 1M-triple cell")
    for (shape, n, tier), c in sorted(by_key.items()):
        tag = f"scale[{shape}@{n}/{tier}]"
        if c.get("trace_count_warm", 0) != 0:
            errors.append(f"{tag} retraced on the warm pass "
                          f"({c['trace_count_warm']} traces)")
        budget = next((kb for lim, kb in sorted(RSS_BUDGET_KB.items())
                       if n <= lim), max(RSS_BUDGET_KB.values()))
        if c.get("rss_peak_kb", 0) > budget:
            errors.append(f"{tag} peak RSS {c['rss_peak_kb']} KiB over "
                          f"the {budget} KiB budget")
        twin = c.get("twin")
        if twin and twin.get("edge_advantage", 0) < 0:
            errors.append(f"{tag} recompaction lost to the "
                          f"no-recompaction twin by "
                          f"{-twin['edge_advantage']} edges")
        if tier != "compressed":
            continue
        p = by_key.get((shape, n, "plain"))
        if p is None:
            errors.append(f"{tag} has no plain-tier counterpart")
            continue
        if c["detect_digest"] != p["detect_digest"]:
            errors.append(f"{tag} detect digest diverged from plain "
                          f"({c['detect_digest']} != "
                          f"{p['detect_digest']})")
        if c["query_digest"] != p["query_digest"]:
            errors.append(f"{tag} query digest diverged from plain "
                          f"({c['query_digest']} != {p['query_digest']})")
        if c["substrate_bytes"] > MAX_COMPRESSED_RATIO * \
                p["substrate_bytes"]:
            errors.append(
                f"{tag} substrate {c['substrate_bytes']} B exceeds "
                f"{MAX_COMPRESSED_RATIO:.0%} of plain "
                f"{p['substrate_bytes']} B")
        if c["decode_peak_resident_bytes"] > \
                MAX_DECODE_RESIDENT_FRAC * p["substrate_bytes"]:
            errors.append(
                f"{tag} streamed detection held "
                f"{c['decode_peak_resident_bytes']} B decoded, over "
                f"{MAX_DECODE_RESIDENT_FRAC:.0%} of the plain substrate")
    return errors


def check_query(query: dict | None) -> list[str]:
    """Gate the star-query latency matrix (see module docstring, item 5)."""
    errors: list[str] = []
    if not query:
        errors.append("snapshot has no query matrix (rerun --snapshot)")
        return errors
    for wname, cells in query.get("workloads", {}).items():
        by_key = {(c["strategy"], c["backend"]): c for c in cells}
        ref = cells[0]
        for c in cells[1:]:
            if c["digest"] != ref["digest"] or c["n_rows"] != ref["n_rows"]:
                errors.append(
                    f"query[{wname}] binding-set parity broken: "
                    f"{c['strategy']}x{c['backend']} digest/rows "
                    f"{c['digest']}/{c['n_rows']} != "
                    f"{ref['digest']}/{ref['n_rows']}")
        dev = by_key.get(("factorized", "device"))
        if dev and dev.get("trace_count_warm", 0) != 0:
            errors.append(
                f"query[{wname}] batched device path retraced on the warm "
                f"pass ({dev['trace_count_warm']} traces)")
        if wname == "lookup_heavy":
            raw = by_key.get(("raw", "host"))
            fact = by_key.get(("factorized", "host"))
            if raw and fact:
                raw_ms = max(raw["exec_time_ms_warm"], MIN_HOST_MS)
                if fact["exec_time_ms_warm"] > raw_ms:
                    errors.append(
                        f"factorized lookup on the frequent-pattern-heavy "
                        f"class is slower than raw: "
                        f"{fact['exec_time_ms_warm']:.1f} ms > "
                        f"{raw_ms:.1f} ms (the 'queries get faster on "
                        f"G\\'' claim regressed)")
            elif not raw or not fact:
                errors.append("query[lookup_heavy] missing raw/factorized "
                              "host cells")
    for wname in ("lookup", "lookup_heavy", "var_arm"):
        if wname not in query.get("workloads", {}):
            errors.append(f"query matrix missing workload {wname!r}")
    return errors


def check_bgp(bgp: dict | None) -> list[str]:
    """Gate the multi-star BGP matrix (see module docstring, item 6)."""
    errors: list[str] = []
    if not bgp:
        errors.append("snapshot has no bgp matrix (rerun --snapshot)")
        return errors
    workloads = bgp.get("workloads", {})
    for wname, cells in workloads.items():
        by_key = {(c["strategy"], c["backend"]): c for c in cells}
        ref = cells[0]
        for c in cells[1:]:
            if c["digest"] != ref["digest"] or c["n_rows"] != ref["n_rows"]:
                errors.append(
                    f"bgp[{wname}] binding-set parity broken: "
                    f"{c['strategy']}x{c['backend']} digest/rows "
                    f"{c['digest']}/{c['n_rows']} != "
                    f"{ref['digest']}/{ref['n_rows']}")
        for (strat, be), c in by_key.items():
            if be == "device" and c.get("trace_count_warm", 0) != 0:
                errors.append(
                    f"bgp[{wname}] {strat}x{be} retraced on the warm "
                    f"pass ({c['trace_count_warm']} traces)")
        if wname == "2star":
            raw = by_key.get(("raw", "host"))
            fact = by_key.get(("factorized", "host"))
            if raw and fact:
                if fact["max_intermediate"] >= raw["max_intermediate"]:
                    errors.append(
                        f"bgp[2star] factorized intermediate "
                        f"{fact['max_intermediate']} not below raw's "
                        f"{raw['max_intermediate']} (molecule-level join "
                        f"-- AMI x AMI -- regressed to entity level)")
            else:
                errors.append("bgp[2star] missing raw/factorized host "
                              "cells")
        if wname == "filter":
            push = by_key.get(("factorized", "host"))
            post = by_key.get(("posthoc", "host"))
            if push and post:
                post_ms = max(post["exec_time_ms_warm"], MIN_HOST_MS)
                if push["exec_time_ms_warm"] > post_ms:
                    errors.append(
                        f"bgp[filter] pushed-down filtering is slower "
                        f"than post-hoc: {push['exec_time_ms_warm']:.1f} "
                        f"ms > {post_ms:.1f} ms (pushdown regressed)")
            else:
                errors.append("bgp[filter] missing pushed/posthoc cells")
        if wname == "mixed":
            plan = by_key.get(("planner", "host"))
            raw = by_key.get(("raw", "host"))
            fact = by_key.get(("factorized", "host"))
            if plan and raw and fact:
                best = max(min(raw["exec_time_ms_warm"],
                               fact["exec_time_ms_warm"]), MIN_HOST_MS)
                if plan["exec_time_ms_warm"] > best:
                    errors.append(
                        f"bgp[mixed] planner warm "
                        f"{plan['exec_time_ms_warm']:.1f} ms is worse "
                        f"than the best fixed strategy {best:.1f} ms "
                        f"(cost model no longer pays for itself)")
            else:
                errors.append("bgp[mixed] missing planner/raw/factorized "
                              "host cells")
        if wname in ("filter", "3star"):
            plan = by_key.get(("planner", "host"))
            raw = by_key.get(("raw", "host"))
            fact = by_key.get(("factorized", "host"))
            if plan and raw and fact:
                best = max(min(raw["exec_time_ms_warm"],
                               fact["exec_time_ms_warm"]), MIN_HOST_MS)
                if plan["exec_time_ms_warm"] > best * MAX_PLANNER_SLACK:
                    errors.append(
                        f"bgp[{wname}] planner warm "
                        f"{plan['exec_time_ms_warm']:.1f} ms exceeds "
                        f"{MAX_PLANNER_SLACK}x the best fixed strategy "
                        f"{best:.1f} ms (the mixed-slot ~25% miss is "
                        f"back -- ROADMAP item 1')")
            else:
                errors.append(f"bgp[{wname}] missing planner/raw/"
                              "factorized host cells")
    for wname in ("lookup", "var_arm", "filter", "2star", "3star",
                  "mixed"):
        if wname not in workloads:
            errors.append(f"bgp matrix missing workload {wname!r}")
    calib = bgp.get("calibration")
    if not calib:
        errors.append("bgp matrix has no cost-model calibration "
                      "(rerun --snapshot)")
    else:
        fitted = calib.get("fitted", {})
        if fitted.get("mix", 0.0) <= 0.0:
            errors.append(
                f"bgp calibration fitted a non-positive mixed-slot "
                f"constant ({fitted.get('mix')!r}) -- the granularity "
                f"crossing no longer costs anything, so the re-pricing "
                f"pass is dead")
        if calib.get("n_samples", 0) < 8:
            errors.append(
                f"bgp calibration ran on {calib.get('n_samples')!r} "
                f"samples (< 8): the fit is underdetermined")
    return errors


def check_drift(drift: dict | None) -> list[str]:
    """Gate the online-compaction drift matrix (module docstring, item 7)."""
    errors: list[str] = []
    if not drift:
        errors.append("snapshot has no drift matrix (rerun --snapshot)")
        return errors
    if not drift.get("drained"):
        errors.append("drift: write-ahead queue did not drain")
    if drift.get("warm_redetect_traces") != 0:
        errors.append(
            f"drift: forced re-detection retraced warm shapes "
            f"({drift.get('warm_redetect_traces')!r} traces, expected 0)")
    if not drift.get("redetect_digest_stable"):
        errors.append("drift: forced re-detect changed graph semantics "
                      "(digest moved)")
    if not drift.get("never_above_baseline"):
        errors.append("drift: service edge count exceeded the "
                      "no-recompaction baseline")
    if not drift.get("redetect_monotone"):
        errors.append("drift: a re-detection pass increased the realized "
                      "edge count (hill-climb guard regressed)")
    if not drift.get("final_gap", 0) < drift.get("first_gap", 0):
        errors.append(
            f"drift: recompaction never beat the no-recompaction twin "
            f"(edge advantage {drift.get('first_gap')} -> "
            f"{drift.get('final_gap')})")
    if not drift.get("batch_parity_digest"):
        errors.append("drift: incremental final state != from-scratch "
                      "compaction of the net graph")
    rows = drift.get("rows", [])
    if len(rows) != drift.get("n_batches"):
        errors.append(
            f"drift: matrix has {len(rows)} rows for "
            f"{drift.get('n_batches')} batches")
    elif not any(r.get("n_dirty") for r in rows):
        errors.append("drift: soak never marked a class dirty -- the "
                      "workload no longer exercises re-detection")
    # fault telemetry must be wired even when nothing fired: the
    # channels are pre-registered by the service, so their absence
    # means the wiring regressed, not that the run was healthy
    metrics = drift.get("metrics", {})
    for ch in ("fault.retries", "fault.dead_workers",
               "ingest.unknown_deletes"):
        if ch not in metrics:
            errors.append(f"drift: metrics summary lost the {ch!r} "
                          f"fault-telemetry channel")
    return errors


# every injection site the crash-point sweep must cover (mirrors
# repro.dist.fault.SITES; listed literally so a silently-shrunk sweep
# fails the gate instead of passing over fewer sites)
RECOVERY_SITES = ("wal.append", "apply", "pre_swap", "post_swap",
                  "checkpoint.write", "redetect")


def check_recovery(recovery: dict | None) -> list[str]:
    """Gate the crash-point recovery matrix (module docstring, item 10)."""
    errors: list[str] = []
    if not recovery:
        errors.append("snapshot has no recovery matrix (rerun --snapshot)")
        return errors
    cells = recovery.get("cells", [])
    swept = {c.get("site") for c in cells}
    for site in RECOVERY_SITES:
        if site not in swept:
            errors.append(f"recovery: injection site {site!r} was never "
                          f"swept")
    for c in cells:
        tag = f"recovery[{c.get('site')}@occ{c.get('occurrence')}]"
        if not c.get("crashed"):
            errors.append(f"{tag} never crashed -- the fault site is "
                          f"dead code or the workload stopped reaching it")
        if not c.get("parity"):
            errors.append(f"{tag} recovered digest diverged from the "
                          f"uninterrupted reference (lost or duplicated "
                          f"writes)")
        if not c.get("drained"):
            errors.append(f"{tag} recovered queue did not drain")
        if c.get("n_recoveries", 0) > 0:
            if c.get("checkpoint_bytes", 0) <= 0:
                errors.append(f"{tag} recovery recorded no checkpoint "
                              f"bytes")
            if "replay_ms" not in c:
                errors.append(f"{tag} recovery recorded no replay cost")
    return errors


def main() -> None:
    path = sys.argv[1] if len(sys.argv) > 1 else DEFAULT_PATH
    errors = check(path)
    if errors:
        for e in errors:
            print(f"FAIL: {e}", file=sys.stderr)
        raise SystemExit(1)
    print(f"snapshot OK: {os.path.abspath(path)}")


if __name__ == "__main__":
    main()

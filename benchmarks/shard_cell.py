"""One (devices x graph size) cell of the shard matrix, in its OWN process.

The parent (``benchmarks.run --shard``) launches this module once per
cell with ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` in the
environment, so an N-device cell sees a real N-device jax host platform
for the cross-shard collective, and ``ru_maxrss`` stays attributable.

What one cell does:

1. generate the sensor workload at the target scale;
2. replicated baseline: one ``CompactionPlanner`` run over the whole
   graph (host detection -- the same engine the shard workers run),
   recording detect wall-clock, the graph digest, and resident bytes;
3. partition into ``devices`` shards (``ShardPlan`` balanced on Def. 4.8
   edge counts, frequent classes chunk-split) and detect shard-local --
   fork-parallel one worker per shard on multi-device cells.  Detection
   runs BEFORE any jax usage in this process, so forked workers never
   inherit a jax runtime;
4. chunk-split classes re-count their global AMI through the
   ``ami_bucketed`` collective over the device mesh (the only detection
   step where signatures cross shards; bytes land in ``traffic``);
5. digest parity: sharded == replicated (Def. 4.10 -- the compact form
   differs per partition, the graph it denotes cannot);
6. the star-query workload runs on the replicated engine and through
   the ``ShardedQueryEngine`` fan-out (device molecule-match backend),
   cold + warm, with per-cell trace counts -- warm must add zero;
7. print a one-line JSON report on the last stdout line.
"""
from __future__ import annotations

import argparse
import hashlib
import json
import os
import resource
import sys
import time

import numpy as np


def _rss_kb() -> int:
    return int(resource.getrusage(resource.RUSAGE_SELF).ru_maxrss)


def _fgraph_nbytes(fg) -> int:
    b = int(fg.store.substrate_nbytes(include_dict=False))
    for t in fg.tables.values():
        b += int(t.surrogates.nbytes) + int(t.objects.nbytes)
    return b


def _build_queries(fg, max_lookups: int = 24, max_var: int = 8):
    from repro.query import StarQuery
    queries = []
    for cid, t in sorted(fg.tables.items()):
        for row in t.objects[:max_lookups]:
            queries.append(StarQuery(
                arms=tuple((int(p), int(o))
                           for p, o in zip(t.props, row)),
                class_id=cid))
        for row in t.objects[:max_var]:
            queries.append(StarQuery(
                arms=((int(t.props[0]), int(row[0])),
                      (int(t.props[-1]), None)),
                class_id=cid))
    return queries


def _digest(bindings) -> str:
    h = hashlib.sha1()
    for b in bindings:
        h.update(np.ascontiguousarray(b.canonical()).tobytes())
    return h.hexdigest()[:16]


def run_cell(devices: int, n_triples: int, seed: int) -> dict:
    from repro.api import CompactionPlanner
    from repro.data.synthetic import WorkloadSpec, generate_workload

    t0 = time.perf_counter()
    store = generate_workload(WorkloadSpec(
        shape="sensor", n_triples=n_triples, seed=seed))
    gen_ms = (time.perf_counter() - t0) * 1e3
    n = store.n_triples

    # replicated baseline: detect over the whole graph in this process
    t0 = time.perf_counter()
    snap, rep = CompactionPlanner("gfsp", "host").run(store.copy())
    detect_repl_ms = (time.perf_counter() - t0) * 1e3
    repl_digest = snap.digest()
    repl_bytes = _fgraph_nbytes(snap.fgraph)

    # partition + shard-local detection (fork-parallel when multi-shard;
    # MUST precede any jax import/use so workers fork a jax-free parent)
    from repro.dist.graph import ShardedFactorizedGraph, ShardedQueryEngine
    t0 = time.perf_counter()
    sharded = ShardedFactorizedGraph.partition(store, devices, oversplit=4)
    partition_ms = (time.perf_counter() - t0) * 1e3
    t0 = time.perf_counter()
    report = sharded.detect_all(backend="host", parallel=devices > 1)
    detect_ms = (time.perf_counter() - t0) * 1e3
    detect_parity = sharded.digest() == repl_digest
    shard_bytes = sharded.shard_nbytes()
    # per-worker detect CPU times: their max is the parallel critical
    # path -- the wall-clock the fork fan-out reaches once every worker
    # has its own core.  Raw wall-clock cannot parallelize on fewer
    # cores than shards, so the matrix records both and the gate arms
    # the wall comparison only where cpu_count covers the shards.
    shard_detect_ms = [report["shards"][sid]["detect_ms"]
                       for sid in sorted(report["shards"])]

    # cross-shard collective AMI over the forced host-device mesh
    collective_ami: dict[str, int] = {}
    if devices > 1:
        import jax

        from repro.launch.mesh import make_mesh_compat
        assert len(jax.devices()) >= devices, \
            (len(jax.devices()), devices)
        mesh = make_mesh_compat((devices,), ("data",))
        for cid in sharded.plan.split_classes:
            got = sharded.cross_shard_ami(cid, mesh=mesh)
            want = report["split_class_ami"][int(cid)]
            assert got == want, (cid, got, want)
            collective_ami[store.dict.term(cid)] = got

    # star-query fan-out: replicated vs sharded, device molecule match
    from repro.core import sweep as core_sweep
    from repro.query import QueryEngine
    queries = _build_queries(snap.fgraph)
    eng_repl = QueryEngine(snap.fgraph)
    res = eng_repl.query_batch(queries, backend="device")
    t0 = time.perf_counter()
    res = eng_repl.query_batch(queries, backend="device")
    query_repl_ms = (time.perf_counter() - t0) * 1e3
    repl_qdigest = _digest(res)

    eng = ShardedQueryEngine(sharded)
    core_sweep.reset_trace_stats()
    t0 = time.perf_counter()
    res = eng.query_batch(queries, backend="device")
    query_cold_ms = (time.perf_counter() - t0) * 1e3
    traces_cold = core_sweep.trace_count()
    t0 = time.perf_counter()
    res = eng.query_batch(queries, backend="device")
    query_warm_ms = (time.perf_counter() - t0) * 1e3
    traces_warm = core_sweep.trace_count() - traces_cold

    return {
        "devices": int(devices), "n_triples": int(n), "seed": seed,
        "gen_ms": round(gen_ms, 1),
        "partition_ms": round(partition_ms, 1),
        "detect_repl_ms": round(detect_repl_ms, 1),
        "detect_ms": round(detect_ms, 1),
        "shard_detect_ms": shard_detect_ms,
        "detect_critical_path_ms": round(max(shard_detect_ms), 1),
        "cpu_count": int(os.cpu_count() or 1),
        "detect_parity": bool(detect_parity),
        "detect_digest": repl_digest,
        "pct_savings_repl": round(float(rep.pct_savings_triples), 2),
        "split_classes": len(sharded.plan.split_classes),
        "collective_ami": collective_ami,
        "shard_weights": [int(w) for w in sharded.plan.shard_weights],
        "repl_resident_bytes": int(repl_bytes),
        "shard_resident_bytes": [int(b) for b in shard_bytes],
        "max_shard_resident_bytes": int(max(shard_bytes)),
        "n_queries": len(queries),
        "query_rows": int(sum(b.n_rows for b in res)),
        "query_repl_ms": round(query_repl_ms, 2),
        "query_cold_ms": round(query_cold_ms, 2),
        "query_warm_ms": round(query_warm_ms, 2),
        "trace_count_cold": int(traces_cold),
        "trace_count_warm": int(traces_warm),
        "query_parity": _digest(res) == repl_qdigest,
        "query_digest": repl_qdigest,
        "traffic": {k: int(v) for k, v in sharded.traffic.items()},
        "rss_peak_kb": _rss_kb(),
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--devices", type=int, required=True)
    ap.add_argument("--n", type=int, required=True)
    ap.add_argument("--seed", type=int, default=7)
    args = ap.parse_args()
    cell = run_cell(args.devices, args.n, args.seed)
    sys.stdout.flush()
    print(json.dumps(cell))


if __name__ == "__main__":
    main()

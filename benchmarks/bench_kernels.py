"""Kernel micro-benchmarks: Pallas (interpret) correctness-path timing vs
the XLA reference; plus the chunked-attention XLA path that the dry-run
lowers.  On CPU these numbers track Python interpreter overhead for the
Pallas bodies -- the structural deliverable is the shapes swept + the
on-TPU dispatch policy, not CPU microseconds."""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.star import ami as ami_host, ami_device
from repro.kernels import ops as kops
from repro.kernels.chunked_attention import chunked_attention
from repro.kernels.ref import mha_ref

from .common import report, timeit


def run(fast: bool = False) -> list[dict]:
    rows = []
    rng = np.random.default_rng(0)

    # FSP group-by: host vs device (sort+seg) paths
    for n in (4_096, 65_536) if not fast else (4_096,):
        mat = rng.integers(0, 50, (n, 4)).astype(np.int32)
        t_host, a_h = timeit(lambda: ami_host(mat))
        dev = jnp.asarray(mat)
        f = jax.jit(lambda m: ami_device(m, use_kernel=False))
        f(dev).block_until_ready()
        t_dev, a_d = timeit(lambda: int(f(dev)))
        assert a_h == a_d
        rows.append({"bench": f"ami_n{n}", "host_ms": round(t_host, 3),
                     "device_xla_ms": round(t_dev, 3)})

    # attention: naive vs chunked (the dry-run path), plus grad
    b, hq, hkv, t, d = 1, 8, 2, 1024, 64
    q = jnp.asarray(rng.standard_normal((b, hq, t, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, hkv, t, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, hkv, t, d)), jnp.float32)
    naive = jax.jit(lambda q, k, v: mha_ref(q, k, v))
    chunk = jax.jit(lambda q, k, v: chunked_attention(q, k, v, chunk=256))
    naive(q, k, v).block_until_ready()
    chunk(q, k, v).block_until_ready()
    t_n, _ = timeit(lambda: naive(q, k, v).block_until_ready())
    t_c, _ = timeit(lambda: chunk(q, k, v).block_until_ready())
    np.testing.assert_allclose(naive(q, k, v), chunk(q, k, v),
                               atol=2e-5, rtol=2e-5)
    rows.append({"bench": f"attn_T{t}", "host_ms": round(t_n, 3),
                 "device_xla_ms": round(t_c, 3)})

    # linear scan (RG-LRU / SSD inter-chunk)
    bt, tt, w = 4, 512, 256
    x = jnp.asarray(rng.standard_normal((bt, tt, w)), jnp.float32)
    a = jnp.asarray(rng.uniform(0.8, 0.99, (bt, tt, w)), jnp.float32)
    ls = jax.jit(lambda x, a: kops.linear_scan(x, a)[1])
    ls(x, a).block_until_ready()
    t_l, _ = timeit(lambda: ls(x, a).block_until_ready())
    rows.append({"bench": f"linear_scan_T{tt}", "host_ms": "",
                 "device_xla_ms": round(t_l, 3)})

    report("kernels_micro", rows)
    return rows


if __name__ == "__main__":
    run()

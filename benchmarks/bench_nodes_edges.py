"""Paper Figure 9: NN + NLE before vs after factorization (Observation
over A5, Measurement over A8), graded datasets.  Validates the paper's
size-reduction claims (obs ~37%, meas ~60% of NN+NLE)."""
from __future__ import annotations

from repro.api import CompactionPlan, Compactor
from repro.data.synthetic import property_set_ids

from .common import DATASETS, dataset, report


def run(fast: bool = False) -> list[dict]:
    rows = []
    comp = Compactor()
    for ds in DATASETS:
        for sid in ("A5", "A8"):
            store = dataset(ds)
            cid, pids = property_set_ids(store, sid)
            res = comp.execute(
                store,
                CompactionPlan.explicit([(cid, pids)])
            ).factorizations[0]
            rows.append({
                "dataset": ds, "SID": sid,
                "NN_before": res.nn_before, "NLE_before": res.nle_before,
                "NN_after": res.nn_after, "NLE_after": res.nle_after,
                "pct_size_savings": round(res.pct_savings_size, 2),
            })
            assert res.pct_savings_size > 0
    report("fig9_nodes_edges", rows)
    return rows


if __name__ == "__main__":
    run()

"""Paper Figure 8: percentage of repeated RDF triples per observation
value (windspeed / temperature / relative humidity): few values cover
most triples (Zipf shape)."""
from __future__ import annotations

import numpy as np

from repro.data.synthetic import P_VALUE

from .common import dataset, report


def run(fast: bool = False) -> list[dict]:
    store = dataset("D1D2D3")
    pv = store.dict.lookup(P_VALUE)
    vals = store.spo[store.spo[:, 1] == pv, 2]
    uniq, counts = np.unique(vals, return_counts=True)
    order = np.argsort(-counts)
    total = counts.sum()
    rows = []
    top = counts[order]
    for k in (1, 5, 10, 20):
        pct = 100.0 * top[:k].sum() / total
        rows.append({"top_values": k, "pct_of_value_triples":
                     round(float(pct), 2)})
    # Fig. 8 claim: the distribution is heavy-headed
    assert rows[0]["pct_of_value_triples"] > 20.0
    report("fig8_repeated_triples", rows)
    return rows


if __name__ == "__main__":
    run()

"""Distributed FSP detection (paper §6 future work, made concrete).

    PYTHONPATH=src python examples/distributed_fsp.py

Runs G.FSP through all three execution backends of the unified pipeline
on the same graph and checks they agree:

  host     the paper-faithful sequential numpy loop
  device   one batched jax lowering per greedy sweep
  sharded  the device sweep row-sharded via the repro.dist planner
           (1 device here; benchmarks/bench_fsp_scale.py lowers the same
           sweep on the production 512-device mesh)

Detection results -- including the subset-evaluation count -- are
backend-invariant by construction (the greedy control flow is shared;
only ``ExecutionBackend.sweep`` differs).
"""
import time

from repro.api import Compactor

from repro.data.synthetic import SensorGraphSpec, generate

store = generate(SensorGraphSpec(n_observations=8000, seed=11))
cid = store.dict.lookup("ssn:Observation")

results, wall_ms = {}, {}
for backend in ("host", "device", "sharded"):
    comp = Compactor(detector="gfsp", backend=backend)
    t0 = time.perf_counter()
    results[backend] = comp.detect(store, cid)
    wall_ms[backend] = (time.perf_counter() - t0) * 1e3

host = results["host"]
names = [store.dict.term(p) for p in host.props]
for res in results.values():
    assert set(res.props) == set(host.props)
    assert res.edges == host.edges
    assert res.evaluations == host.evaluations

print(f"FSP over {names}: #Edges={host.edges}, {host.n_fsp} patterns, "
      f"{host.evaluations} subset evaluations (backend-invariant)")
label = {"host": "", "device": "  (batched candidate sweep)",
         "sharded": "  (row-sharded; 1 device here)"}
for backend in results:
    print(f"{backend:8s}{wall_ms[backend]:8.1f} ms{label[backend]}")
print("all three backends agree — distributed_fsp OK")

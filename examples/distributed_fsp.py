"""Distributed FSP detection (paper §6 future work, made concrete).

    PYTHONPATH=src python examples/distributed_fsp.py

Runs G.FSP three ways on the same graph and checks they agree:
  host (paper-faithful) / device batched sweep / mesh-sharded sweep.
The production-mesh lowering of the sweep (512 devices) is exercised by
``benchmarks/bench_fsp_scale.py`` -- this example stays 1-device.
"""
import time

from repro.core import gfsp
from repro.core.distributed import gfsp_distributed
from repro.data.synthetic import SensorGraphSpec, generate

store = generate(SensorGraphSpec(n_observations=8000, seed=11))
cid = store.dict.lookup("ssn:Observation")

t0 = time.perf_counter()
host = gfsp(store, cid)
t_host = time.perf_counter() - t0

t0 = time.perf_counter()
dev = gfsp(store, cid, device_sweep=True)
t_dev = time.perf_counter() - t0

t0 = time.perf_counter()
dist = gfsp_distributed(store, cid)
t_dist = time.perf_counter() - t0

names = [store.dict.term(p) for p in host.props]
assert set(host.props) == set(dev.props) == set(dist.props)
assert host.edges == dev.edges == dist.edges
print(f"FSP over {names}: #Edges={host.edges}, {host.n_fsp} patterns")
print(f"host      {t_host * 1e3:8.1f} ms")
print(f"device    {t_dev * 1e3:8.1f} ms   (batched candidate sweep)")
print(f"sharded   {t_dist * 1e3:8.1f} ms   (row-sharded; 1 device here)")
print("all three agree — distributed_fsp OK")

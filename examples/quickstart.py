"""Quickstart: the paper's full pipeline on a LinkedSensorData-style graph.

    PYTHONPATH=src python examples/quickstart.py

1. generate a synthetic SSN sensor graph (paper §5 datasets);
2. detect frequent star patterns with G.FSP (Algorithm 2);
3. factorize them into compact RDF molecules (Algorithm 3);
4. verify the factorized graph is smaller AND lossless (Def. 4.10/4.11);
5. answer the same query on both graphs via instanceOf-aware rewriting.
"""
import numpy as np

from repro.core import (factorize, gfsp, match_star, semantic_triples)
from repro.data.synthetic import SensorGraphSpec, generate

store = generate(SensorGraphSpec(n_observations=3000, seed=7))
print(f"original graph: {store.n_triples} triples, {store.n_nodes} nodes")

for cname in ("ssn:Observation", "ssn:Measurement"):
    cid = store.dict.lookup(cname)
    res = gfsp(store, cid)
    names = [store.dict.term(p) for p in res.props]
    print(f"\n{cname}: G.FSP found {res.n_fsp} frequent star patterns over "
          f"{names}\n  #Edges={res.edges}  iterations={res.iterations}  "
          f"time={res.exec_time_ms:.1f}ms")

    fact = factorize(store, cid, res.props)
    print(f"  factorized: NLE {fact.nle_before} -> {fact.nle_after} "
          f"({fact.pct_savings_nle:+.1f}% savings)")

    # losslessness: axiom expansion of G' == semantic closure of G
    a, b = semantic_triples(store), semantic_triples(fact.graph)
    assert a.shape == b.shape and (a == b).all()
    print("  lossless: axiom expansion reproduces the original graph")

    # query both graphs: who measured value val/0?
    if cname == "ssn:Measurement":
        v = store.dict.lookup("val/0")
        p = store.dict.lookup("ssn:value")
        orig = np.sort(match_star(store, [(p, v)], rewrite=False))
        new = np.sort(match_star(fact.graph, [(p, v)], rewrite=True))
        assert (orig == new).all() and orig.size > 0
        print(f"  query 'value=val/0': {orig.size} matches on both graphs")

print("\nquickstart OK")

"""Quickstart: the paper's full pipeline through the unified ``repro.api``.

    PYTHONPATH=src python examples/quickstart.py

1. generate a synthetic SSN sensor graph (paper §5 datasets);
2. ``Compactor.run``: rank every class by predicted #Edges savings
   (Def. 4.8), detect frequent star patterns with G.FSP (Algorithm 2),
   and factorize the winners into compact RDF molecules (Algorithm 3) in
   one transaction;
3. verify the factorized graph is smaller AND lossless (Def. 4.10/4.11);
4. answer the same query on both graphs via instanceOf-aware rewriting;
5. ``Compactor.update``: absorb streaming inserts incrementally -- a new
   observation whose star pattern already exists just links to its
   surrogate, no recomputation.
"""
import numpy as np

from repro.api import Compactor
from repro.core import match_star, semantic_triples
from repro.data.synthetic import SensorGraphSpec, generate

store = generate(SensorGraphSpec(n_observations=3000, seed=7))
print(f"original graph: {store.n_triples} triples, {store.n_nodes} nodes")

# -- 2. plan + detect + factorize, all classes, one call --------------------
comp = Compactor(detector="gfsp", backend="host")
report = comp.run(store)
for entry in report.plan:
    cname = store.dict.term(entry.class_id)
    res = entry.detection
    names = [store.dict.term(p) for p in res.props]
    fact = report.factorization_for(entry.class_id)
    print(f"\n{cname}: G.FSP found {res.n_fsp} frequent star patterns over "
          f"{names}\n  #Edges={res.edges}  predicted_savings="
          f"{entry.predicted_savings} edges  time={res.exec_time_ms:.1f}ms")
    print(f"  factorized: NLE {fact.nle_before} -> {fact.nle_after} "
          f"({fact.pct_savings_nle:+.1f}% savings)")

print(f"\ncompacted: {report.n_triples_before} -> {report.n_triples_after} "
      f"triples ({report.pct_savings_triples:.1f}% smaller)")

# -- 3. losslessness: axiom closure of G' == semantic closure of G ----------
a, b = semantic_triples(store), semantic_triples(report.graph)
assert a.shape == b.shape and (a == b).all()
print("lossless: axiom expansion reproduces the original graph")

# -- 4. query both graphs: who measured value val/0? ------------------------
v = store.dict.lookup("val/0")
p = store.dict.lookup("ssn:value")
orig = np.sort(match_star(store, [(p, v)], rewrite=False))
new = np.sort(match_star(report.graph, [(p, v)], rewrite=True))
assert (orig == new).all() and orig.size > 0
print(f"query 'value=val/0': {orig.size} matches on both graphs")

# -- 5. streaming inserts: incremental re-factorization ---------------------
up = comp.update([
    ("obs/new", "rdf:type", "ssn:Observation"),
    ("obs/new", "ssn:observedProperty", "phenom/Temperature"),
    ("obs/new", "ssn:procedure", "sensor/1"),
    ("obs/new", "ssn:generatedBy", "sensor/1"),
    ("obs/new", "ssn:samplingTime", "time/5"),
])
print(f"update: absorbed {up.n_entities_absorbed} entity "
      f"({up.n_surrogates_reused} existing star patterns reused, "
      f"{up.n_new_surrogates} minted) in {up.exec_time_ms:.1f}ms")

print("\nquickstart OK")

"""Factorized serving demo: the paper's compact-RDF-molecule idea applied
to shared prompt prefixes (see serving/prefix_factorization.py).

    PYTHONPATH=src python examples/serve_prefix.py

Serves two workloads through the batched engine:
  * chat-like (75% shared system prompt)  -> planner factorizes, one
    molecule prefill replaces N identical prefills;
  * all-distinct prompts                  -> planner declines (the
    paper's Fig. 7 factorization-overhead case).
Both paths are asserted token-identical to flat serving (losslessness).
"""
from repro.launch.serve import main as serve_main

print("== workload A: shared system prompt ==")
out = serve_main(["--arch", "qwen2-0.5b", "--requests", "8",
                  "--prompt-len", "96", "--shared-frac", "0.75",
                  "--max-new", "8"])
assert out["plan_savings_pct"] > 0

print("\n== workload B: fully distinct prompts (overhead case) ==")
out = serve_main(["--arch", "qwen2-0.5b", "--requests", "8",
                  "--prompt-len", "96", "--shared-frac", "0.0",
                  "--max-new", "8"])
assert out["plan_savings_pct"] == 0.0
print("\nserve_prefix OK")

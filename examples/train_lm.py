"""End-to-end LM training with the production substrate on CPU.

    PYTHONPATH=src python examples/train_lm.py [--arch qwen2-0.5b] [--steps 200]

Trains a reduced same-family config for a few hundred steps with the full
stack engaged -- deterministic sharded pipeline, AdamW + cosine schedule,
async atomic checkpointing, resume-from-checkpoint -- and asserts the
loss actually falls.  On a TPU slice, drop --reduced to train the full
config on the production mesh (launch/dryrun.py proves those lowerings).
"""
import argparse
import sys

from repro.launch.train import main as train_main

ap = argparse.ArgumentParser()
ap.add_argument("--arch", default="qwen2-0.5b")
ap.add_argument("--steps", type=int, default=200)
ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
args = ap.parse_args()

out = train_main(["--arch", args.arch, "--reduced",
                  "--steps", str(args.steps), "--batch", "8",
                  "--seq", "64", "--lr", "3e-3",
                  "--ckpt-dir", args.ckpt_dir, "--ckpt-every", "50",
                  "--log-every", "20"])
drop = out["first_loss"] - out["final_loss"]
print(f"\nloss {out['first_loss']:.3f} -> {out['final_loss']:.3f} "
      f"(drop {drop:.3f})")
if drop <= 0:
    sys.exit("loss did not decrease")
